"""Figure 1 reproduction: small-data accuracy, ours vs multilinear + TGP
baselines, 5-fold CV protocol (k folds configurable for CPU budgets).

Paper claims reproduced here:
  * ours (GP on concatenated factors, balanced entries) beats CP/Tucker;
  * balanced sampling helps CP too (CP-2 > CP) — the bias argument;
  * ours >= InfTucker (run on a shrunken dense variant: InfTucker needs the
    ENTIRE tensor — the Kronecker restriction is the paper's motivation).
"""
from __future__ import annotations

import argparse

import numpy as np

from benchmarks.common import (
    Table, eval_scores, prepare_folds, run_cp, run_ours, run_tucker,
)
from repro.core import baselines
from repro.data import make_dense_nonlinear_tensor


SCALES = {"alog": 1.0, "adclick": 0.7, "enron": 1.0, "nellsmall": 1.0}


def run(datasets=("alog", "adclick", "enron", "nellsmall"), folds=2, max_nnz=8000,
        steps=200, inducing=64, seed=0):
    results = {}
    for name in datasets:
        tensor, binary, fold_sets = prepare_folds(
            name, seed=seed, folds=folds, max_nnz=max_nnz, dim_scale=SCALES.get(name, 1.0)
        )
        metric = "AUC" if binary else "MSE"
        tbl = Table(f"{name} dims={tensor.dims} nnz={tensor.nnz}", metric)
        agg = {}
        for train, test in fold_sets:
            for method, fn in [
                ("ours-GD", lambda: run_ours(tensor, binary, train, test, optimizer="adam",
                                             steps=steps, inducing=inducing, seed=seed)),
                ("ours-LBFGS", lambda: run_ours(tensor, binary, train, test, optimizer="lbfgs",
                                                steps=steps, inducing=inducing, seed=seed)),
                ("CP", lambda: run_cp(tensor, binary, train, test, balanced=False, seed=seed)),
                ("CP-2 (balanced)", lambda: run_cp(tensor, binary, train, test, balanced=True, seed=seed)),
                ("Tucker", lambda: run_tucker(tensor, binary, train, test, seed=seed)),
            ]:
                v, dt = fn()
                agg.setdefault(method, []).append((v, dt))
        for method, vals in agg.items():
            tbl.add(method, float(np.mean([v for v, _ in vals])), sum(d for _, d in vals))
        tbl.show()
        results[name] = {m: float(np.mean([v for v, _ in vals])) for m, vals in agg.items()}

    # InfTucker head-to-head on a small dense tensor (its feasible regime)
    rng = np.random.default_rng(seed)
    dense, _ = make_dense_nonlinear_tensor(rng, (24, 20, 22))
    dims = dense.shape
    grid = np.stack(np.meshgrid(*[np.arange(d) for d in dims], indexing="ij"), -1).reshape(-1, 3)
    vals = dense.reshape(-1)
    hold = rng.permutation(len(vals))[: len(vals) // 5]
    mask = np.ones(len(vals), bool)
    mask[hold] = False
    from repro.data.tensor_store import EntrySet, SparseTensor

    train = EntrySet(grid[mask].astype(np.int32), vals[mask])
    test = EntrySet(grid[hold].astype(np.int32), vals[hold])
    tensor = SparseTensor(dims=dims, idx=train.idx, vals=train.y)

    it = baselines.fit_inftucker(np.where(mask, vals, 0.0).reshape(dims), steps=60, seed=seed)
    s_it = baselines.inftucker_predict(it, dims, test.idx)
    v_it = eval_scores(False, test.y, s_it)
    v_ours, _ = run_ours(tensor, False, train, test, steps=steps, inducing=inducing, seed=seed)
    tbl = Table(f"dense {dims} (InfTucker feasible regime)", "MSE")
    tbl.add("ours-GD", v_ours, 0)
    tbl.add("InfTucker", v_it, 0)
    tbl.show()
    results["dense_inftucker"] = {"ours": v_ours, "inftucker": v_it}
    return results


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--folds", type=int, default=2)
    ap.add_argument("--max-nnz", type=int, default=1200)
    ap.add_argument("--steps", type=int, default=120)
    args = ap.parse_args()
    run(folds=args.folds, max_nnz=args.max_nnz, steps=args.steps)
