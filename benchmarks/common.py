"""Shared benchmark scaffolding: dataset prep per the paper's §6 protocol,
method runners, and a tiny result table printer."""
from __future__ import annotations

import time

import numpy as np

from repro.core import baselines
from repro.core.model import DFNTF, FitConfig
from repro.data import balanced_train_test, kfold_split, make_sparse_tensor
from repro.utils.metrics import auc, mse

BINARY_SETS = {"enron", "nellsmall", "dblp", "nell", "ctr_day"}


def prepare_folds(name, seed=0, folds=2, max_nnz=1500, dim_scale=1.0):
    tensor, truth = make_sparse_tensor(name, seed=seed, max_nnz=max_nnz, dim_scale=dim_scale)
    binary = name in BINARY_SETS
    rng = np.random.default_rng(seed)
    out = []
    for train_rows, test_rows in kfold_split(rng, tensor, folds=folds)[:folds]:
        train, test = balanced_train_test(rng, tensor, train_rows, test_rows, binary=binary)
        out.append((train, test))
    return tensor, binary, out


def eval_scores(binary, y_true, scores):
    return auc(y_true, scores) if binary else mse(y_true, scores)


def run_ours(tensor, binary, train, test, *, optimizer="adam", steps=150, rank=3,
             inducing=50, seed=0):
    cfg = FitConfig(
        task="binary" if binary else "continuous",
        rank=rank, num_inducing=inducing, optimizer=optimizer,
        steps=steps, learning_rate=2e-2, seed=seed,
    )
    model = DFNTF(tensor.dims, cfg)
    t0 = time.time()
    model.fit(train)
    dt = time.time() - t0
    s = model.predict_proba(test.idx) if binary else model.predict(test.idx)
    return eval_scores(binary, test.y, s), dt


def run_cp(tensor, binary, train, test, *, balanced, steps=300, rank=3, seed=0):
    # CP-2 = CP on the balanced train set (zeros included); plain CP sees
    # only the nonzeros (the paper's CP setting).
    if balanced:
        data = train
    else:
        from repro.data.tensor_store import EntrySet

        keep = train.y != 0
        data = EntrySet(train.idx[keep], train.y[keep])
    t0 = time.time()
    model = baselines.fit_cp(data, tensor.dims, rank=rank, steps=steps, seed=seed)
    dt = time.time() - t0
    s = np.asarray(model.score(test.idx))
    return eval_scores(binary, test.y, s), dt


def run_tucker(tensor, binary, train, test, *, steps=300, rank=3, seed=0):
    t0 = time.time()
    model = baselines.fit_tucker(train, tensor.dims, rank=rank, steps=steps, seed=seed)
    dt = time.time() - t0
    s = np.asarray(model.score(test.idx))
    return eval_scores(binary, test.y, s), dt


class Table:
    def __init__(self, title, metric):
        self.title, self.metric, self.rows = title, metric, []

    def add(self, method, value, seconds):
        self.rows.append((method, value, seconds))

    def show(self):
        print(f"\n## {self.title}  ({self.metric})")
        for m, v, s in self.rows:
            print(f"  {m:24s} {self.metric}={v:.4f}  ({s:.1f}s)")
