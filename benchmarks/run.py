"""Run every paper benchmark with CPU-budget sizes.

  PYTHONPATH=src python -m benchmarks.run            # full suite
  PYTHONPATH=src python -m benchmarks.run --only ctr # one table/figure
"""
from __future__ import annotations

import argparse
import time

BENCHES = ["small_data", "large", "scalability", "reduce", "fixed_point", "ctr", "kernels"]


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None, choices=BENCHES)
    args = ap.parse_args()

    selected = [args.only] if args.only else BENCHES
    t0 = time.time()
    results = {}
    for name in selected:
        print(f"\n================ benchmarks.bench_{name} ================")
        t = time.time()
        mod = __import__(f"benchmarks.bench_{name}", fromlist=["run"])
        results[name] = mod.run()
        print(f"[bench_{name}: {time.time() - t:.1f}s]")
    print(f"\nall benchmarks done in {time.time() - t0:.1f}s")
    return results


if __name__ == "__main__":
    main()
