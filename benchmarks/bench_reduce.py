"""§4.3.2 reproduction: key-value-free reduce vs keyed shuffle.

The paper reports ~30x on a 100^3 tensor on Spark.  The JAX/TPU analogue:
  * key-value-free — every mapper produces a FULL dense gradient vector for
    the factor matrices; the reduce is a single dense sum (psum).  Cost is
    O(sum_k d_k r) per mapper, independent of which entries it owns.
  * keyed          — every entry emits K (mode, row) -> grad_row pairs; the
    reducer must group by key (sort) and segment-sum.  This is the shuffle
    the paper avoids; we emulate it faithfully with sort + segment_sum.

Both produce identical gradients (asserted); we time them.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.data import make_sparse_tensor


def run(n_entries=50000, rank=8, seed=0, reps=5):
    tensor, _ = make_sparse_tensor("alog", seed=seed, max_nnz=n_entries)
    dims = tensor.dims
    K = len(dims)
    n = tensor.nnz
    rng = np.random.default_rng(seed)
    idx = jnp.asarray(tensor.idx)
    # per-(entry, mode) gradient rows, stand-in for dElbo/du_{i_k}
    grads = jnp.asarray(rng.normal(size=(n, K, rank)).astype(np.float32))
    offsets = np.concatenate([[0], np.cumsum(dims)[:-1]]).astype(np.int32)
    total_rows = int(sum(dims))

    @jax.jit
    def keyvalue_free(idx, grads):
        # mapper: scatter-add into its FULL gradient vector; reducer: dense sum
        out = jnp.zeros((total_rows, rank), jnp.float32)
        for k in range(K):
            out = out.at[idx[:, k] + offsets[k]].add(grads[:, k])
        return out

    @jax.jit
    def keyed_shuffle(idx, grads):
        # emulate emit(key=(mode,row), value=grad) -> sort by key -> segment sum
        keys = (idx + offsets[None, :]).reshape(-1)  # (n*K,)
        vals = grads.reshape(-1, rank)
        order = jnp.argsort(keys)  # THE shuffle: data movement by key
        keys_s = keys[order]
        vals_s = vals[order]
        return jax.ops.segment_sum(vals_s, keys_s, num_segments=total_rows)

    a = keyvalue_free(idx, grads)
    b = keyed_shuffle(idx, grads)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-4, atol=1e-4)

    def timeit(fn):
        jax.block_until_ready(fn(idx, grads))
        t0 = time.time()
        for _ in range(reps):
            jax.block_until_ready(fn(idx, grads))
        return (time.time() - t0) / reps

    t_free = timeit(keyvalue_free)
    t_kv = timeit(keyed_shuffle)
    print(f"\n## key-value-free vs keyed reduce (N={n}, K={K}, r={rank})")
    print(f"  key-value-free: {t_free * 1e3:8.2f} ms")
    print(f"  keyed shuffle : {t_kv * 1e3:8.2f} ms")
    print(f"  speedup       : {t_kv / t_free:8.1f}x  (paper reports ~30x on Spark)")
    return {"t_free": t_free, "t_keyed": t_kv, "speedup": t_kv / t_free}


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--entries", type=int, default=50000)
    args = ap.parse_args()
    run(n_entries=args.entries)
