"""§4.3.1 reproduction: the lambda fixed-point inner loop (Eq. 8, Lemma 4.3)
vs optimizing lambda jointly by gradient.

Claims checked: (1) each fixed-point sweep MONOTONICALLY increases the tight
binary ELBO L2*; (2) fixed-point + outer gradient reaches a given ELBO in
fewer outer iterations than the all-gradient variant."""
from __future__ import annotations

import argparse
import time

import numpy as np

from repro.core.model import DFNTF, FitConfig
from benchmarks.common import prepare_folds


def run(max_nnz=800, steps=60, inducing=40, seed=0):
    tensor, binary, fold_sets = prepare_folds("enron", seed=seed, folds=2, max_nnz=max_nnz)
    assert binary
    train, _ = fold_sets[0]

    print("\n## lambda fixed-point (Lemma 4.3) vs gradient-only")
    results = {}
    for name, fp_iters in [("fixed-point (paper)", 5), ("gradient-only", 0)]:
        cfg = FitConfig(task="binary", rank=3, num_inducing=inducing, optimizer="adam",
                        steps=steps, learning_rate=2e-2, fixed_point_iters=fp_iters,
                        seed=seed)
        model = DFNTF(tensor.dims, cfg)
        t0 = time.time()
        hist = model.fit(train)
        dt = time.time() - t0
        elbos = hist.get("elbo", [])
        final = model.elbo()
        print(f"  {name:22s} final ELBO={final:10.2f}  ({dt:.1f}s, {steps} outer steps)")
        results[name] = final

    # monotonicity of the pure fixed-point iteration at fixed (U, B)
    import jax.numpy as jnp

    from repro.core.inference import InferenceConfig, make_elbo_fn, make_lambda_update
    from repro.data.loader import pad_to_multiple

    cfg = FitConfig(task="binary", rank=3, num_inducing=inducing, seed=seed)
    model = DFNTF(tensor.dims, cfg)
    batch = pad_to_multiple(train, 1)
    idx, y, w = jnp.asarray(batch.idx), jnp.asarray(batch.y), jnp.asarray(batch.w)
    icfg = InferenceConfig(task="binary")
    elbo_fn = make_elbo_fn(icfg)
    lam_up = make_lambda_update(icfg)
    params = model.params
    prev = float(elbo_fn(params, idx, y, w))
    mono = True
    for it in range(8):
        params = lam_up(params, idx, y, w)
        cur = float(elbo_fn(params, idx, y, w))
        mono &= cur >= prev - 1e-6
        print(f"  fp sweep {it}: L2* = {cur:.4f} ({'+' if cur >= prev else 'VIOLATION'})")
        prev = cur
    print(f"  monotone: {mono} (Lemma 4.3)")
    results["monotone"] = mono
    return results


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=60)
    args = ap.parse_args()
    run(steps=args.steps)
