"""Kernel micro-benchmarks: Pallas gp_gram + flash_attention vs jnp refs.

On this CPU container the Pallas kernels run in interpret mode (Python
executed — NOT indicative of TPU speed); the benchmark's role here is a
correctness + shape-sweep harness and an HLO-size comparison.  The jnp path
timings are real CPU numbers.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np


def run(seed=0):
    print("\n## kernel micro-benchmarks (CPU: jnp timed; Pallas = interpret-mode check)")
    key = jax.random.PRNGKey(seed)

    # flash attention: jnp chunked path
    from repro.kernels.flash_attention import flash_attention
    from repro.models.layers import chunked_attention

    B, S, H, hd = 2, 1024, 8, 64
    kq, kk, kv = jax.random.split(key, 3)
    q = jax.random.normal(kq, (B, S, H, hd), jnp.float32)
    k = jax.random.normal(kk, (B, S, H, hd), jnp.float32)
    v = jax.random.normal(kv, (B, S, H, hd), jnp.float32)

    f = jax.jit(lambda q, k, v: chunked_attention(q, k, v, q_chunk=256, kv_chunk=256))
    jax.block_until_ready(f(q, k, v))
    t0 = time.time()
    for _ in range(3):
        jax.block_until_ready(f(q, k, v))
    t_jnp = (time.time() - t0) / 3
    print(f"  chunked attention jnp (B{B} S{S} H{H}): {t_jnp * 1e3:.1f} ms")

    got = flash_attention(q[:, :256], k[:, :256], v[:, :256], interpret=True)
    want = flash_attention(q[:, :256], k[:, :256], v[:, :256], use_ref=True)
    err = float(jnp.max(jnp.abs(got - want)))
    print(f"  flash_attention pallas interpret max|err| vs ref: {err:.2e}")

    # gp_gram kernel vs jnp stats
    from repro.kernels.gp_gram.ops import gram_stats
    from repro.kernels.gp_gram import ref as gram_ref

    N, D, Pp = 4096, 9, 64
    ks = jax.random.split(key, 3)
    xs = jax.random.normal(ks[0], (N, D), jnp.float32)
    bs = jax.random.normal(ks[1], (Pp, D), jnp.float32)
    y = jax.random.normal(ks[2], (N,), jnp.float32)
    w = jnp.ones((N,), jnp.float32)
    from repro.core.gp import KernelParams

    kp = KernelParams(log_lengthscale=jnp.zeros((D,)), log_amplitude=jnp.zeros(()))

    t0 = time.time()
    ref_out = gram_ref.gram_stats_ref("ard", kp, xs, bs, y, w, None)
    jax.block_until_ready(jax.tree.leaves(ref_out))
    t_ref = time.time() - t0
    print(f"  gp_gram jnp ref (N={N}, p={Pp}): {t_ref * 1e3:.1f} ms (first call)")
    pal = gram_stats("ard", kp, xs, bs, y, w, None, tile_n=512, interpret=True)
    for a, b in zip(jax.tree.leaves(pal), jax.tree.leaves(ref_out)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=2e-4, atol=2e-4)
    print("  gp_gram pallas interpret == ref: ok")
    return {"attention_jnp_ms": t_jnp * 1e3}


if __name__ == "__main__":
    argparse.ArgumentParser().parse_args()
    run()
