"""Table 1 reproduction: CTR prediction, ours vs logistic regression vs
linear SVM on the 4-mode (user, ad, publisher, page-section) tensor.

The Yahoo logs are proprietary; the generator reproduces the tensor's shape
family, extreme sparsity and click/non-click balance (see data/synthetic.py).
Paper: ours 0.89-0.90 AUC vs LR/SVM 0.73-0.75 (+20%)."""
from __future__ import annotations

import argparse

import numpy as np

from benchmarks.common import Table, prepare_folds, run_ours
from repro.core import baselines
from repro.utils.metrics import auc


def run(max_nnz=12000, steps=200, inducing=64, seed=0):
    tensor, binary, fold_sets = prepare_folds("ctr_day", seed=seed, folds=2, max_nnz=max_nnz)
    assert binary
    train, test = fold_sets[0]
    tbl = Table(f"CTR 4-mode dims={tensor.dims} nnz={tensor.nnz}", "AUC")

    v_ours, dt = run_ours(tensor, True, train, test, steps=steps, inducing=inducing, seed=seed)
    tbl.add("ours (DFNTF)", v_ours, dt)

    lr = baselines.fit_linear(train, tensor.dims, loss_kind="logistic", seed=seed)
    tbl.add("logistic regression", auc(test.y, np.asarray(lr.score(np.asarray(test.idx)))), 0)

    svm = baselines.fit_linear(train, tensor.dims, loss_kind="hinge", seed=seed)
    tbl.add("linear SVM", auc(test.y, np.asarray(svm.score(np.asarray(test.idx)))), 0)
    tbl.show()
    return {r[0]: r[1] for r in tbl.rows}


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--max-nnz", type=int, default=12000)
    ap.add_argument("--steps", type=int, default=150)
    args = ap.parse_args()
    run(max_nnz=args.max_nnz, steps=args.steps)
