"""Figure 2(a) reproduction: scalability vs number of mappers.

The paper shows running SPEED (1/time) scaling linearly with machines.  The
algorithmic reason is separability: each mapper computes sufficient stats
over its N/T slice in O(p^2 N/T), and the reduce is a fixed-size sum.  On
the single-CPU container we measure exactly that: per-mapper wall time on an
N/T slice (the parallel critical path), plus the fixed (p x p) reduce cost.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import elbo as elbo_mod
from repro.core.inference import InferenceConfig, make_stats_fn
from repro.data import make_sparse_tensor


def run(workers=(1, 2, 4, 8, 16), n_entries=20000, inducing=100, rank=3, seed=0):
    tensor, _ = make_sparse_tensor("acc", seed=seed, max_nnz=n_entries)
    n = min(n_entries, tensor.nnz)
    idx = jnp.asarray(tensor.idx[:n])
    y = jnp.asarray(tensor.vals[:n])
    w = jnp.ones(n, jnp.float32)
    params = elbo_mod.init_params(
        jax.random.PRNGKey(seed), tensor.dims, rank, num_inducing=inducing
    )
    icfg = InferenceConfig(kernel_kind="ard", task="continuous")
    stats_fn = make_stats_fn(icfg)

    def time_slice(m):
        sl = slice(0, n // m)
        fn = jax.jit(lambda p, i, yy, ww: stats_fn(p, i, yy, ww))
        fn(params, idx[sl], y[sl], w[sl])  # compile + warm
        reps = 3
        t0 = time.time()
        for _ in range(reps):
            jax.block_until_ready(fn(params, idx[sl], y[sl], w[sl]))
        return (time.time() - t0) / reps

    t1 = None
    rows = []
    print(f"\n## scalability (N={n}, p={inducing}; per-mapper critical path)")
    for m in workers:
        t = time_slice(m)
        t1 = t1 or t
        speed = t1 / t
        rows.append((m, t, speed))
        print(f"  mappers={m:3d}  mapper-time={t * 1e3:8.2f}ms  speedup={speed:6.2f}x  (ideal {m}x)")
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--entries", type=int, default=20000)
    args = ap.parse_args()
    run(n_entries=args.entries)
