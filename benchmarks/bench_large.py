"""Figure 2(b-d) reproduction: large-tensor accuracy (ACC, DBLP, NELL
footprints), ours vs distributed CP (GigaTensor's model class).

The paper's cluster-scale datasets are size-capped for the CPU container;
shapes/sparsity match §6.2, and the protocol (80% train, multiple sampled
test sets of nonzeros + zeros) matches §6.3.

Caveat recorded in EXPERIMENTS.md: `acc` keeps the paper's density at ~40x
reduced dims, leaving its 3000-wide mode with <1 observation/row — every
factor model degenerates there (CP collapses to the zero predictor and
"wins" MSE); dblp/nell at healthier coverage reproduce the paper's ordering.
"""
from __future__ import annotations

import argparse

import numpy as np

from benchmarks.common import Table, eval_scores, prepare_folds, run_cp, run_ours


def run(datasets=("acc", "dblp", "nell"), max_nnz=3000, steps=120, inducing=50,
        test_sets=5, seed=0):
    results = {}
    for name in datasets:
        scales = {"acc": 0.22, "dblp": 0.28, "nell": 0.28}
        tensor, binary, fold_sets = prepare_folds(
            name, seed=seed, folds=5, max_nnz=max_nnz, dim_scale=scales.get(name, 1.0)
        )
        train, _ = fold_sets[0]
        metric = "AUC" if binary else "MSE"
        tbl = Table(f"{name} dims={tensor.dims} nnz={tensor.nnz}", metric)

        # train once, evaluate on `test_sets` sampled test sets (paper: 50)
        from repro.core.model import DFNTF, FitConfig

        cfg = FitConfig(task="binary" if binary else "continuous", rank=3,
                        num_inducing=inducing, optimizer="adam", steps=steps,
                        learning_rate=2e-2, seed=seed)
        model = DFNTF(tensor.dims, cfg)
        model.fit(train)

        cp_v, _ = run_cp(tensor, binary, train, fold_sets[0][1], balanced=True, seed=seed)
        ours_vals = []
        rng = np.random.default_rng(seed + 1)
        from repro.data import balanced_train_test, kfold_split

        for t in range(test_sets):
            tr_rows, te_rows = kfold_split(rng, tensor, folds=5)[t % 5]
            _, test = balanced_train_test(rng, tensor, tr_rows, te_rows, binary=binary)
            s = model.predict_proba(test.idx) if binary else model.predict(test.idx)
            ours_vals.append(eval_scores(binary, test.y, s))
        tbl.add(f"ours (avg {test_sets} test sets)", float(np.mean(ours_vals)), 0)
        tbl.add("CP (distributed class)", cp_v, 0)
        tbl.show()
        results[name] = {"ours": float(np.mean(ours_vals)), "cp": cp_v}
    return results


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--max-nnz", type=int, default=3000)
    ap.add_argument("--steps", type=int, default=120)
    args = ap.parse_args()
    run(max_nnz=args.max_nnz, steps=args.steps)
