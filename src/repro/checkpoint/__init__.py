from repro.checkpoint.checkpoint import CheckpointManager, restore, save
