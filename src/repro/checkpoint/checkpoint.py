"""Pytree checkpointing via msgpack (no pickle; safe to load).

Arrays are stored as (dtype, shape, raw bytes); the tree structure is restored
against a caller-provided template pytree, so arbitrary code can never be
deserialized.  Supports step-numbered checkpoints with retention.
"""
from __future__ import annotations

import os
import re
from typing import Any

import jax
import msgpack
import numpy as np

_EXT = ".ckpt.msgpack"


def _np_dtype(name: str) -> np.dtype:
    """Resolve a dtype name, including ml_dtypes extensions (bfloat16 etc.)."""
    try:
        return np.dtype(name)
    except TypeError:
        import ml_dtypes

        return np.dtype(getattr(ml_dtypes, name))


def _encode_leaf(x) -> dict:
    arr = np.asarray(x)
    return {
        b"dtype": arr.dtype.name.encode(),
        b"shape": list(arr.shape),
        b"data": arr.tobytes(),
    }


def _decode_leaf(d: dict) -> np.ndarray:
    return np.frombuffer(d[b"data"], dtype=_np_dtype(d[b"dtype"].decode())).reshape(
        d[b"shape"]
    )


def save(path: str, tree: Any) -> None:
    leaves = jax.tree.leaves(tree)
    payload = msgpack.packb([_encode_leaf(l) for l in leaves], use_bin_type=True)
    tmp = path + ".tmp"
    os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
    with open(tmp, "wb") as f:
        f.write(payload)
    os.replace(tmp, path)


def restore(path: str, template: Any) -> Any:
    with open(path, "rb") as f:
        raw = msgpack.unpackb(f.read(), raw=True)
    leaves = [_decode_leaf(d) for d in raw]
    treedef = jax.tree.structure(template)
    t_leaves = jax.tree.leaves(template)
    if len(leaves) != len(t_leaves):
        raise ValueError(
            f"checkpoint has {len(leaves)} leaves, template has {len(t_leaves)}"
        )
    out = []
    for got, want in zip(leaves, t_leaves):
        want_arr = np.asarray(want)
        if tuple(got.shape) != tuple(want_arr.shape):
            raise ValueError(f"shape mismatch: {got.shape} vs {want_arr.shape}")
        out.append(got.astype(want_arr.dtype))
    return jax.tree.unflatten(treedef, out)


class CheckpointManager:
    """Step-numbered checkpoints in a directory, keeping the newest `keep`."""

    def __init__(self, directory: str, keep: int = 3):
        self.directory = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)

    def _path(self, step: int) -> str:
        return os.path.join(self.directory, f"step_{step:010d}{_EXT}")

    def all_steps(self) -> list[int]:
        pat = re.compile(r"step_(\d+)" + re.escape(_EXT) + "$")
        steps = []
        for name in os.listdir(self.directory):
            m = pat.match(name)
            if m:
                steps.append(int(m.group(1)))
        return sorted(steps)

    def latest_step(self) -> int | None:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def save(self, step: int, tree: Any) -> str:
        path = self._path(step)
        save(path, tree)
        for old in self.all_steps()[: -self.keep]:
            os.remove(self._path(old))
        return path

    def restore(self, template: Any, step: int | None = None) -> tuple[Any, int]:
        step = self.latest_step() if step is None else step
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.directory}")
        return restore(self._path(step), template), step
