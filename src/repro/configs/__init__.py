from repro.configs.base import (
    SHAPES, ArchConfig, ShapeConfig, get_arch, get_reduced, list_archs, register,
)
