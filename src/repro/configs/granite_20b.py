"""granite-20b [dense] — llama-arch code model, MQA (kv=1) [arXiv:2405.04324]."""
import dataclasses

from repro.configs.base import ArchConfig, register

CONFIG = ArchConfig(
    name="granite-20b",
    family="dense",
    citation="arXiv:2405.04324 (IBM Granite Code 20B)",
    num_layers=52,
    d_model=6144,
    num_heads=48,
    num_kv_heads=1,  # MQA
    d_ff=24576,
    vocab_size=49152,
)


def reduced() -> ArchConfig:
    return dataclasses.replace(
        CONFIG, num_layers=2, d_model=256, num_heads=4, num_kv_heads=1,
        d_ff=512, vocab_size=512,
    )


register(CONFIG, reduced)
