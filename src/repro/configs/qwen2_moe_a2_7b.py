"""qwen2-moe-a2.7b [moe] — 60 routed experts top-4 + 4 shared experts,
fine-grained expert d_ff=1408 [hf:Qwen/Qwen1.5-MoE-A2.7B]."""
import dataclasses

from repro.configs.base import ArchConfig, register

CONFIG = ArchConfig(
    name="qwen2-moe-a2.7b",
    family="moe",
    citation="hf:Qwen/Qwen1.5-MoE-A2.7B model card",
    num_layers=24,
    d_model=2048,
    num_heads=16,
    num_kv_heads=16,
    d_ff=1408,  # per-expert hidden dim (fine-grained experts)
    vocab_size=151936,
    num_experts=60,
    experts_per_token=4,
    num_shared_experts=4,
    qkv_bias=True,
)


def reduced() -> ArchConfig:
    return dataclasses.replace(
        CONFIG, num_layers=2, d_model=256, num_heads=4, num_kv_heads=4,
        d_ff=128, vocab_size=512, num_experts=4, experts_per_token=2,
        num_shared_experts=1,
    )


register(CONFIG, reduced)
