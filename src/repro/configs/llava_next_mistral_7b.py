"""llava-next-mistral-7b [vlm] — Mistral-7B decoder consuming anyres image
patch embeddings; the SigLIP/CLIP vision tower + projector are STUBS providing
precomputed patch embeddings [hf:llava-hf/llava-v1.6-mistral-7b-hf]."""
import dataclasses

from repro.configs.base import ArchConfig, register

CONFIG = ArchConfig(
    name="llava-next-mistral-7b",
    family="vlm",
    citation="hf:llava-hf/llava-v1.6-mistral-7b-hf model card",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    d_ff=14336,
    vocab_size=32000,
    modality="vision",
    frontend_tokens=2880,  # anyres: 5 tiles x 576 patches (24x24 @ 336px)
)


def reduced() -> ArchConfig:
    return dataclasses.replace(
        CONFIG, num_layers=2, d_model=256, num_heads=4, num_kv_heads=2,
        d_ff=512, vocab_size=512, frontend_tokens=16,
    )


register(CONFIG, reduced)
