"""musicgen-medium [audio] — decoder-only over EnCodec tokens; the EnCodec
conv codec + text conditioner are STUBS providing precomputed conditioning
embeddings [arXiv:2306.05284]."""
import dataclasses

from repro.configs.base import ArchConfig, register

CONFIG = ArchConfig(
    name="musicgen-medium",
    family="audio",
    citation="arXiv:2306.05284 (MusicGen medium)",
    num_layers=48,
    d_model=1536,
    num_heads=24,
    num_kv_heads=24,
    d_ff=6144,
    vocab_size=2048,  # EnCodec codebook
    modality="audio",
    frontend_tokens=64,  # stub conditioning embeddings (T5-text stand-in)
)


def reduced() -> ArchConfig:
    return dataclasses.replace(
        CONFIG, num_layers=2, d_model=256, num_heads=4, num_kv_heads=4,
        d_ff=512, vocab_size=512, frontend_tokens=8,
    )


register(CONFIG, reduced)
