"""zamba2-1.2b [hybrid] — Mamba2 backbone + one SHARED attention block applied
periodically [arXiv:2411.15242]."""
import dataclasses

from repro.configs.base import ArchConfig, register

CONFIG = ArchConfig(
    name="zamba2-1.2b",
    family="hybrid",
    citation="arXiv:2411.15242 (Zamba2 1.2B)",
    num_layers=38,
    d_model=2048,
    num_heads=32,
    num_kv_heads=32,
    d_ff=8192,
    vocab_size=32000,
    ssm_state=64,
    ssm_head_dim=64,
    ssm_expand=2,
    ssm_conv=4,
    ssm_chunk=256,
    shared_attn_every=6,  # shared transformer block every 6 mamba layers
    # long-context decode: the shared attention block uses a sliding window
    # (full 500k KV for the shared block would defeat the SSM's O(1) state)
    sliding_window=4096,
)


def reduced() -> ArchConfig:
    return dataclasses.replace(
        CONFIG, num_layers=4, d_model=256, num_heads=4, num_kv_heads=4,
        d_ff=512, vocab_size=512, ssm_state=16, ssm_head_dim=32,
        ssm_chunk=32, shared_attn_every=2, sliding_window=64,
    )


register(CONFIG, reduced)
