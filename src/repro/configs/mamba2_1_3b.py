"""mamba2-1.3b [ssm] — attention-free SSD (state-space duality) [arXiv:2405.21060]."""
import dataclasses

from repro.configs.base import ArchConfig, register

CONFIG = ArchConfig(
    name="mamba2-1.3b",
    family="ssm",
    citation="arXiv:2405.21060 (Mamba-2, SSD)",
    num_layers=48,
    d_model=2048,
    num_heads=0,  # attention-free
    num_kv_heads=0,
    d_ff=0,
    vocab_size=50280,
    ssm_state=128,
    ssm_head_dim=64,
    ssm_expand=2,
    ssm_conv=4,
    ssm_chunk=256,
)


def reduced() -> ArchConfig:
    return dataclasses.replace(
        CONFIG, num_layers=2, d_model=256, vocab_size=512, ssm_state=32,
        ssm_head_dim=32, ssm_chunk=32,
    )


register(CONFIG, reduced)
