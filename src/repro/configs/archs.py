"""Import all architecture configs (self-registering)."""
from repro.configs import (  # noqa: F401
    deepseek_7b,
    granite_20b,
    llava_next_mistral_7b,
    mamba2_1_3b,
    mixtral_8x22b,
    musicgen_medium,
    qwen2_72b,
    qwen2_moe_a2_7b,
    qwen3_0_6b,
    zamba2_1_2b,
)
