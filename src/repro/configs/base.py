"""Architecture + input-shape configuration registry.

Every assigned architecture is one ``ArchConfig`` (src/repro/configs/<id>.py,
citing its source), selectable via ``--arch <id>`` in the launchers.  Each
config also provides a REDUCED variant (<= 2 layers, d_model <= 512,
<= 4 experts) used by the CPU smoke tests; the full configs are exercised
only through the dry-run (ShapeDtypeStruct, no allocation).
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import jax.numpy as jnp

FAMILIES = ("dense", "moe", "ssm", "hybrid", "audio", "vlm")


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str
    citation: str

    num_layers: int = 0
    d_model: int = 0
    num_heads: int = 0  # 0 => attention-free
    num_kv_heads: int = 0
    d_ff: int = 0
    vocab_size: int = 0
    head_dim: int = 0  # 0 => d_model // num_heads

    # attention details
    qk_norm: bool = False
    qkv_bias: bool = False
    sliding_window: int = 0  # 0 => full attention
    rope_theta: float = 10000.0

    # MoE
    num_experts: int = 0
    experts_per_token: int = 0
    num_shared_experts: int = 0
    moe_d_ff: int = 0  # per-expert hidden dim (defaults to d_ff)

    # SSM (Mamba2 / SSD)
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    ssm_conv: int = 4
    ssm_chunk: int = 128

    # hybrid: apply a SHARED attention+MLP block every k-th layer (zamba2)
    shared_attn_every: int = 0

    # modality frontend stubs ([audio]/[vlm]: the transformer backbone
    # consumes precomputed frame/patch embeddings; the conv codec / ViT is
    # NOT implemented, per the assignment carve-out)
    modality: str = "text"  # text | audio | vision
    frontend_tokens: int = 0  # stub embedding count per example

    norm_eps: float = 1e-5
    dtype: str = "bfloat16"

    # Fully unroll the over-layers scan.  Production lowering keeps the scan
    # (HLO O(1) in depth); the roofline-analysis dry-run unrolls it so that
    # cost_analysis / collective-byte parsing see every layer (XLA's
    # HloCostAnalysis counts a while body ONCE regardless of trip count).
    scan_unroll: bool = False
    # Also unroll the loops INSIDE a layer (attention q/kv chunks, SSD
    # inter-chunk recurrence).  Only viable at validation scale (small S) —
    # used by tests/test_roofline.py to calibrate the analytic op model.
    inner_unroll: bool = False

    # ---- beyond-paper performance levers (EXPERIMENTS.md §Perf).
    # Cast weights to the activation dtype BEFORE the FSDP all-gather
    # (constraining the gathered form to bf16) — halves all-gather bytes.
    bf16_weight_gather: bool = False
    # Replicate weights over the data axis (no FSDP): removes per-layer
    # weight all-gathers entirely.  Only valid when params fit replicated
    # per model-shard (small archs).
    no_fsdp: bool = False
    # Store weights in bf16 (f32 Adam moments stay) — FSDP all-gathers move
    # bf16 by dtype, the robust form of the gather lever.
    bf16_params: bool = False
    # Downcast cotangents entering the layer stack to the activation dtype:
    # the f32 CE loss otherwise propagates f32 cotangents through every
    # backward dx all-reduce (observed 2x collective bytes).
    bf16_cotangents: bool = False
    # Remat policy: save each sublayer's post-all-reduce output instead of
    # recomputing it — removes the 2-per-layer REMAT re-psums at the cost of
    # 2 x (tokens x d_model) bf16 saves per layer.
    remat_save_outputs: bool = False

    # ------------------------------------------------------------- derived

    @property
    def resolved_head_dim(self) -> int:
        if self.head_dim:
            return self.head_dim
        return self.d_model // max(self.num_heads, 1)

    @property
    def resolved_moe_d_ff(self) -> int:
        return self.moe_d_ff or self.d_ff

    @property
    def attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def d_inner(self) -> int:  # SSD inner width
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    @property
    def activation_dtype(self):
        return jnp.dtype(self.dtype)

    def supports_seq_len(self, seq_len: int) -> bool:
        """Sub-quadratic requirement for very long sequences (>= 128k)."""
        if seq_len < 131072:
            return True
        return self.family in ("ssm", "hybrid") or self.sliding_window > 0

    def with_long_context_window(self, window: int = 4096) -> "ArchConfig":
        """The sliding-window VARIANT used to run full-attention archs on
        long_500k (allowed by the assignment; recorded in the roofline
        table as '<name>+swa')."""
        if self.sliding_window:
            return self
        return dataclasses.replace(self, sliding_window=window)

    def param_count(self) -> int:
        """Approximate parameter count (embeddings + blocks), for 6ND."""
        d, l = self.d_model, self.num_layers
        total = self.vocab_size * d  # embedding (tied output head)
        hd = self.resolved_head_dim
        if self.family in ("dense", "moe", "audio", "vlm", "hybrid"):
            attn = d * hd * self.num_heads + 2 * d * hd * self.num_kv_heads + hd * self.num_heads * d
            if self.family == "hybrid":
                # one SHARED attention+MLP block
                total += attn + 3 * d * self.d_ff
            else:
                total += l * attn
        if self.family in ("dense", "audio", "vlm"):
            total += l * 3 * d * self.d_ff
        if self.family == "moe":
            e_ff = self.resolved_moe_d_ff
            total += l * (self.num_experts * 3 * d * e_ff + d * self.num_experts)
            total += l * self.num_shared_experts * 3 * d * e_ff
        if self.family in ("ssm", "hybrid"):
            di, s = self.d_inner, self.ssm_state
            h = self.ssm_heads
            per = d * (2 * di + 2 * s + h) + di * self.ssm_conv + di * d
            total += l * per
        return int(total)

    def active_param_count(self) -> int:
        """Active-per-token params (MoE: only routed top-k experts count)."""
        if self.family != "moe":
            return self.param_count()
        d, l = self.d_model, self.num_layers
        hd = self.resolved_head_dim
        e_ff = self.resolved_moe_d_ff
        total = self.vocab_size * d
        total += l * (
            d * hd * self.num_heads + 2 * d * hd * self.num_kv_heads + hd * self.num_heads * d
        )
        active = self.experts_per_token + self.num_shared_experts
        total += l * (active * 3 * d * e_ff + d * self.num_experts)
        return int(total)


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    mode: str  # "train" | "prefill" | "decode"


SHAPES: dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}

_REGISTRY: dict[str, ArchConfig] = {}
_REDUCED: dict[str, Callable[[], ArchConfig]] = {}


def register(cfg: ArchConfig, reduced: Callable[[], ArchConfig]) -> ArchConfig:
    if cfg.family not in FAMILIES:
        raise ValueError(f"bad family {cfg.family}")
    _REGISTRY[cfg.name] = cfg
    _REDUCED[cfg.name] = reduced
    return cfg


def get_arch(name: str) -> ArchConfig:
    _ensure_loaded()
    return _REGISTRY[name]


def get_reduced(name: str) -> ArchConfig:
    _ensure_loaded()
    return _REDUCED[name]()


def list_archs() -> list[str]:
    _ensure_loaded()
    return sorted(_REGISTRY)


def _ensure_loaded():
    # import the per-arch modules (they self-register)
    from repro.configs import archs  # noqa: F401
