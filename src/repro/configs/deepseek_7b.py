"""deepseek-7b [dense] — llama-arch, full MHA (kv=32) [arXiv:2401.02954]."""
import dataclasses

from repro.configs.base import ArchConfig, register

CONFIG = ArchConfig(
    name="deepseek-7b",
    family="dense",
    citation="arXiv:2401.02954 (DeepSeek LLM 7B)",
    num_layers=30,
    d_model=4096,
    num_heads=32,
    num_kv_heads=32,
    d_ff=11008,
    vocab_size=102400,
)


def reduced() -> ArchConfig:
    return dataclasses.replace(
        CONFIG, num_layers=2, d_model=256, num_heads=4, num_kv_heads=4,
        d_ff=512, vocab_size=512,
    )


register(CONFIG, reduced)
