"""qwen2-72b [dense] — GQA kv=8, QKV bias [arXiv:2407.10671]."""
import dataclasses

from repro.configs.base import ArchConfig, register

CONFIG = ArchConfig(
    name="qwen2-72b",
    family="dense",
    citation="arXiv:2407.10671 (Qwen2 72B)",
    num_layers=80,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    d_ff=29568,
    vocab_size=152064,
    qkv_bias=True,
    rope_theta=1_000_000.0,
)


def reduced() -> ArchConfig:
    return dataclasses.replace(
        CONFIG, num_layers=2, d_model=256, num_heads=4, num_kv_heads=2,
        d_ff=512, vocab_size=512,
    )


register(CONFIG, reduced)
