"""qwen3-0.6b [dense] — qk_norm, GQA (kv=8), head_dim 128 [hf:Qwen/Qwen3-8B]."""
import dataclasses

from repro.configs.base import ArchConfig, register

CONFIG = ArchConfig(
    name="qwen3-0.6b",
    family="dense",
    citation="hf:Qwen/Qwen3-8B model card (0.6B sibling)",
    num_layers=28,
    d_model=1024,
    num_heads=16,
    num_kv_heads=8,
    d_ff=3072,
    vocab_size=151936,
    head_dim=128,  # decoupled from d_model/num_heads in Qwen3
    qk_norm=True,
    rope_theta=1_000_000.0,
)


def reduced() -> ArchConfig:
    return dataclasses.replace(
        CONFIG, num_layers=2, d_model=256, num_heads=4, num_kv_heads=2,
        head_dim=64, d_ff=512, vocab_size=512,
    )


register(CONFIG, reduced)
