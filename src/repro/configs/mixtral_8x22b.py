"""mixtral-8x22b [moe] — 8 experts top-2, GQA kv=8, SWA [arXiv:2401.04088]."""
import dataclasses

from repro.configs.base import ArchConfig, register

CONFIG = ArchConfig(
    name="mixtral-8x22b",
    family="moe",
    citation="arXiv:2401.04088 (Mixtral of Experts)",
    num_layers=56,
    d_model=6144,
    num_heads=48,
    num_kv_heads=8,
    d_ff=16384,
    vocab_size=32768,
    num_experts=8,
    experts_per_token=2,
    sliding_window=4096,
)


def reduced() -> ArchConfig:
    return dataclasses.replace(
        CONFIG, num_layers=2, d_model=256, num_heads=4, num_kv_heads=2,
        d_ff=512, vocab_size=512, num_experts=4, experts_per_token=2,
        sliding_window=64,
    )


register(CONFIG, reduced)
