"""Tight variational evidence lower bounds (Theorems 4.1 and 4.2).

Both bounds are closed-form functions of the model parameters and the
additive sufficient statistics from ``core.stats`` — the optimal variational
posteriors q(v) (and q(z) for binary data) have been substituted analytically,
which is what makes fully-decoupled distributed computation possible.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.core import gp, linalg
from repro.core.stats import SuffStats


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class DFNTFParams:
    """All learnable parameters of the factorization model.

    factors:   tuple of U^{(k)}, each [d_k, r_k] (standard-normal prior).
    inducing:  B, [p, sum_k r_k].
    kernel:    KernelParams (log lengthscale / amplitude).
    log_beta:  scalar, noise precision (continuous likelihood only).
    lam:       [p] convex-conjugate variational parameter (binary only);
               optimized by the fixed-point iteration, not by the outer
               gradient steps.
    """

    factors: tuple[jax.Array, ...]
    inducing: jax.Array
    kernel: gp.KernelParams
    log_beta: jax.Array
    lam: jax.Array

    @property
    def beta(self) -> jax.Array:
        return jnp.exp(self.log_beta)

    @property
    def num_inducing(self) -> int:
        return self.inducing.shape[0]

    @property
    def input_dim(self) -> int:
        return self.inducing.shape[1]


def init_params(
    key: jax.Array,
    dims: tuple[int, ...],
    rank: int | tuple[int, ...],
    num_inducing: int = 100,
    kernel_kind: str = "ard",
    factor_scale: float = 0.1,
    lengthscale: float = 1.0,
    amplitude: float = 1.0,
    beta: float = 1.0,
    dtype=jnp.float32,
) -> DFNTFParams:
    """Random initialization matching the paper's setup (p=100, ARD kernel)."""
    ranks = (rank,) * len(dims) if isinstance(rank, int) else tuple(rank)
    if len(ranks) != len(dims):
        raise ValueError("rank tuple must match number of modes")
    keys = jax.random.split(key, len(dims) + 1)
    factors = tuple(
        factor_scale * jax.random.normal(keys[k], (dims[k], ranks[k]), dtype)
        for k in range(len(dims))
    )
    input_dim = sum(ranks)
    inducing = jax.random.normal(keys[-1], (num_inducing, input_dim), dtype) * factor_scale
    return DFNTFParams(
        factors=factors,
        inducing=inducing,
        kernel=gp.init_kernel_params(kernel_kind, input_dim, lengthscale, amplitude, dtype),
        log_beta=jnp.asarray(jnp.log(beta), dtype),
        lam=jnp.zeros((num_inducing,), dtype),
    )


def _log_prior_factors(params: DFNTFParams) -> jax.Array:
    """-1/2 sum_k ||U^(k)||_F^2 (standard-normal prior, up to a constant)."""
    return -0.5 * sum(jnp.sum(u * u) for u in params.factors)


def elbo_continuous(
    kind: str, params: DFNTFParams, stats: SuffStats, jitter: float = linalg.DEFAULT_JITTER
) -> jax.Array:
    """L1* of Theorem 4.1 from psum-able sufficient statistics.

    L1* = 1/2 log|Kbb| - 1/2 log|Kbb + beta A1| - beta/2 a2 - beta/2 a3
          + beta/2 tr(Kbb^{-1} A1) - 1/2 sum_k ||U^k||_F^2
          + beta^2/2 a4^T (Kbb + beta A1)^{-1} a4 + N/2 log(beta / 2 pi)

    Computed in WHITENED form: with L = chol(Kbb), A1w = L^-1 A1 L^-T and
    M = I + beta A1w,
        1/2 log|Kbb| - 1/2 log|Kbb + beta A1| = -1/2 log|M|
        tr(Kbb^-1 A1) = tr(A1w)
        a4^T (Kbb + beta A1)^-1 a4 = a4w^T M^-1 a4w,  a4w = L^-1 a4.
    chol(M) has unit-plus diagonal and never fails in f32 even when the
    learned noise precision beta grows to ~1e4 (the direct chol does).
    """
    beta = params.beta
    kbb = gp.kernel_matrix(kind, params.kernel, params.inducing, params.inducing)
    chol_kbb = linalg.safe_cholesky(kbb, jitter)
    a1w = linalg.whiten(chol_kbb, stats.a1)
    p = kbb.shape[0]
    m = jnp.eye(p, dtype=kbb.dtype) + beta * a1w
    chol_m = linalg.safe_cholesky(m, jitter)
    a4w = linalg.whiten_vec(chol_kbb, stats.a4)
    return (
        -0.5 * linalg.chol_logdet(chol_m)
        - 0.5 * beta * stats.a2
        - 0.5 * beta * stats.a3
        + 0.5 * beta * jnp.trace(a1w)
        + _log_prior_factors(params)
        + 0.5 * beta**2 * linalg.quad_form_solve(chol_m, a4w)
        + 0.5 * stats.n * (params.log_beta - jnp.log(2.0 * jnp.pi))
    )


def elbo_binary(
    kind: str,
    params: DFNTFParams,
    stats: SuffStats,
    s_phi: jax.Array,
    jitter: float = linalg.DEFAULT_JITTER,
) -> jax.Array:
    """L2* of Theorem 4.2 from psum-able statistics.

    L2* = 1/2 log|Kbb| - 1/2 log|Kbb + A1| - 1/2 a3
          + sum_j log Phi((2y_j-1) lam^T k(B, x_j))        (= s_phi)
          - 1/2 lam^T Kbb lam + 1/2 tr(Kbb^{-1} A1)
          - 1/2 sum_k ||U^k||_F^2
    """
    kbb = gp.kernel_matrix(kind, params.kernel, params.inducing, params.inducing)
    chol_kbb = linalg.safe_cholesky(kbb, jitter)
    a1w = linalg.whiten(chol_kbb, stats.a1)
    p = kbb.shape[0]
    chol_m = linalg.safe_cholesky(jnp.eye(p, dtype=kbb.dtype) + a1w, jitter)
    return (
        -0.5 * linalg.chol_logdet(chol_m)
        - 0.5 * stats.a3
        + s_phi
        - 0.5 * params.lam @ (kbb @ params.lam)
        + 0.5 * jnp.trace(a1w)
        + _log_prior_factors(params)
    )


# --------------------------------------------------------------------------
# Whitened-feature bounds (production path).
#
# The raw bounds above whiten the SUMMED A1, whose f32 error grows with
# cond(Kbb) * beta and can make I + beta*A1w indefinite.  The production path
# instead whitens each FEATURE (phi = L^-1 k, applied as one extra matmul in
# the statistics pass — see core/stats.py), so the summed gram is PSD by
# construction.  The math is identical (verified in test_elbo_whitened.py).
# --------------------------------------------------------------------------


def whiten_operator(
    kind: str, params: DFNTFParams, jitter: float = linalg.DEFAULT_JITTER
) -> tuple[jax.Array, jax.Array]:
    """(chol_kbb, whiten_inv = L^{-1}) for the whitened statistics pass."""
    kbb = gp.kernel_matrix(kind, params.kernel, params.inducing, params.inducing)
    chol_kbb = linalg.safe_cholesky(kbb, jitter)
    return chol_kbb, linalg.triangular_inverse(chol_kbb)


def elbo_continuous_whitened(
    params: DFNTFParams, wstats: SuffStats, jitter: float = linalg.DEFAULT_JITTER
) -> jax.Array:
    """L1* from WHITENED statistics (wstats.a1 = sum w phi phi^T etc.).

    -1/2 log|I + beta A1w| - beta/2 (a2 + a3) + beta/2 tr(A1w)
    + beta^2/2 a4w^T (I + beta A1w)^{-1} a4w - 1/2 sum||U||^2
    + n/2 log(beta/2pi)
    """
    beta = params.beta
    p = wstats.a1.shape[0]
    m = jnp.eye(p, dtype=wstats.a1.dtype) + beta * wstats.a1
    chol_m = linalg.safe_cholesky(m, jitter)
    return (
        -0.5 * linalg.chol_logdet(chol_m)
        - 0.5 * beta * wstats.a2
        - 0.5 * beta * wstats.a3
        + 0.5 * beta * jnp.trace(wstats.a1)
        + _log_prior_factors(params)
        + 0.5 * beta**2 * linalg.quad_form_solve(chol_m, wstats.a4)
        + 0.5 * wstats.n * (params.log_beta - jnp.log(2.0 * jnp.pi))
    )


def elbo_binary_whitened(
    params: DFNTFParams,
    wstats: SuffStats,
    s_phi: jax.Array,
    lam_w: jax.Array,
    jitter: float = linalg.DEFAULT_JITTER,
) -> jax.Array:
    """L2* from WHITENED statistics; lam_w = L^T lam, so lam^T Kbb lam =
    ||lam_w||^2 and s_phi was computed against lam_w^T phi == lam^T k."""
    p = wstats.a1.shape[0]
    chol_m = linalg.safe_cholesky(
        jnp.eye(p, dtype=wstats.a1.dtype) + wstats.a1, jitter
    )
    return (
        -0.5 * linalg.chol_logdet(chol_m)
        - 0.5 * wstats.a3
        + s_phi
        - 0.5 * jnp.sum(lam_w * lam_w)
        + 0.5 * jnp.trace(wstats.a1)
        + _log_prior_factors(params)
    )


def lam_step_whitened(
    a1w: jax.Array, a5_w: jax.Array, lam_w: jax.Array,
    jitter: float = linalg.DEFAULT_JITTER,
) -> jax.Array:
    """Fixed-point update (Eq. 8) entirely in the whitened basis.

    lam_w <- (I + A1w)^{-1} (A1w lam_w + a5w); converting back to the raw
    basis is lam = L^{-T} lam_w (only needed for prediction).
    """
    p = a1w.shape[0]
    chol_m = linalg.safe_cholesky(jnp.eye(p, dtype=a1w.dtype) + a1w, jitter)
    return linalg.chol_solve(chol_m, a1w @ lam_w + a5_w)


def optimal_qv_continuous(
    kind: str, params: DFNTFParams, stats: SuffStats, jitter: float = linalg.DEFAULT_JITTER
) -> tuple[jax.Array, jax.Array]:
    """Optimal q(v) = N(mu, Lambda) recovered from the statistics.

    mu     = beta Kbb (Kbb + beta A1)^{-1} a4 = beta L M^{-1} L^{-1} a4
    Lambda = Kbb (Kbb + beta A1)^{-1} Kbb     = L M^{-1} L^T
    (whitened forms; L = chol(Kbb), M = I + beta L^{-1} A1 L^{-T})
    """
    beta = params.beta
    kbb = gp.kernel_matrix(kind, params.kernel, params.inducing, params.inducing)
    chol_kbb = linalg.safe_cholesky(kbb, jitter)
    p = kbb.shape[0]
    m = jnp.eye(p, dtype=kbb.dtype) + beta * linalg.whiten(chol_kbb, stats.a1)
    chol_m = linalg.safe_cholesky(m, jitter)
    a4w = linalg.whiten_vec(chol_kbb, stats.a4)
    mu = beta * (chol_kbb @ linalg.chol_solve(chol_m, a4w))
    lam_cov = chol_kbb @ linalg.chol_solve(chol_m, chol_kbb.T)
    return mu, lam_cov
