"""Predictive posterior of the GP factorization model.

With the optimal q(v) substituted, prediction at a new entry x* collapses to
small closed forms (p x p solves only):

  continuous:  m* = beta k(x*,B) (Kbb + beta A1)^{-1} a4
               v* = k** - k*B [Kbb^{-1} - (Kbb + beta A1)^{-1}] k*B^T
  binary:      f* mean = k(x*,B) lam*      (at the converged fixed point,
               mu_v = Kbb lam*, hence k*B Kbb^{-1} mu_v = k*B lam*)
               P(y*=1) = Phi(m* / sqrt(1 + v*))
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp

from repro.core import gp, linalg
from repro.core.elbo import DFNTFParams
from repro.core.stats import SuffStats


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class PosteriorCache:
    """Small precomputed solves shared across prediction batches.

    Whitened representation (L = chol(Kbb), M = I + c L^-1 A1 L^-T with
    c = beta for continuous, 1 for binary):
      alpha  : predictive-mean weights, m* = k(x*, B) alpha
      chol_kbb = L;  chol_m = chol(M)
      v* = k** - ||L^-1 k*||^2 + ||chol_m^-1 L^-1 k*||^2
    """

    alpha: jax.Array  # [p]
    chol_kbb: jax.Array  # [p, p]
    chol_m: jax.Array  # [p, p]


def build_cache(
    kind: str,
    params: DFNTFParams,
    wstats: SuffStats,
    chol_kbb: jax.Array,
    task: str = "continuous",
    jitter: float = linalg.DEFAULT_JITTER,
) -> PosteriorCache:
    """Build from WHITENED statistics (wstats.a1 = A1w, wstats.a4 = a4w)."""
    p = chol_kbb.shape[0]
    eye = jnp.eye(p, dtype=chol_kbb.dtype)
    if task == "continuous":
        beta = params.beta
        chol_m = linalg.safe_cholesky(eye + beta * wstats.a1, jitter)
        # alpha = beta (Kbb + beta A1)^{-1} a4 = beta L^{-T} M^{-1} a4w
        alpha = beta * jax.scipy.linalg.solve_triangular(
            chol_kbb.T, linalg.chol_solve(chol_m, wstats.a4), lower=False
        )
    elif task == "binary":
        chol_m = linalg.safe_cholesky(eye + wstats.a1, jitter)
        alpha = params.lam
    else:
        raise ValueError(f"unknown task {task!r}")
    return PosteriorCache(alpha=alpha, chol_kbb=chol_kbb, chol_m=chol_m)


@partial(jax.jit, static_argnames=("kind",))
def predict_f(
    kind: str, params: DFNTFParams, cache: PosteriorCache, idx: jax.Array
) -> tuple[jax.Array, jax.Array]:
    """Latent mean/variance at entries idx [N, K] -> ([N], [N])."""
    xs = gp.gather_inputs(params.factors, idx)
    kxb = gp.kernel_matrix(kind, params.kernel, xs, params.inducing)  # [N, p]
    mean = kxb @ cache.alpha
    # v* = k** - ||L^-1 k*||^2 + ||chol_m^-1 L^-1 k*||^2
    w_kbb = jax.scipy.linalg.solve_triangular(cache.chol_kbb, kxb.T, lower=True)
    w_m = jax.scipy.linalg.solve_triangular(cache.chol_m, w_kbb, lower=True)
    kdiag = gp.kernel_diag(kind, params.kernel, xs)
    var = kdiag - jnp.sum(w_kbb * w_kbb, axis=0) + jnp.sum(w_m * w_m, axis=0)
    return mean, jnp.maximum(var, 1e-10)


@partial(jax.jit, static_argnames=("kind",))
def predict_y_continuous(
    kind: str, params: DFNTFParams, cache: PosteriorCache, idx: jax.Array
) -> tuple[jax.Array, jax.Array]:
    """Observation-space mean/variance (adds noise 1/beta)."""
    mean, var = predict_f(kind, params, cache, idx)
    return mean, var + 1.0 / params.beta


@partial(jax.jit, static_argnames=("kind",))
def predict_proba(
    kind: str, params: DFNTFParams, cache: PosteriorCache, idx: jax.Array
) -> jax.Array:
    """P(y=1) under the Probit link, marginalizing the latent Gaussian."""
    mean, var = predict_f(kind, params, cache, idx)
    return jax.scipy.stats.norm.cdf(mean / jnp.sqrt(1.0 + var))
