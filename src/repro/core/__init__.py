# The paper's primary contribution: flexible GP tensor factorization with
# tight ELBOs (Thm 4.1/4.2), the lambda fixed point (Lemma 4.3), and the
# key-value-free distributed inference step (psum-aggregated statistics).
from repro.core.elbo import DFNTFParams, elbo_binary, elbo_continuous, init_params
from repro.core.fixed_point import lam_step, run_fixed_point
from repro.core.gp import KernelParams, gather_inputs, init_kernel_params, kernel_diag, kernel_matrix
from repro.core.predict import PosteriorCache, build_cache, predict_f, predict_proba, predict_y_continuous
from repro.core.stats import SuffStats, binary_stats, sufficient_stats

__all__ = [
    "DFNTFParams", "KernelParams", "PosteriorCache", "SuffStats",
    "binary_stats", "build_cache", "elbo_binary", "elbo_continuous",
    "gather_inputs", "init_kernel_params", "init_params", "kernel_diag",
    "kernel_matrix", "lam_step", "predict_f", "predict_proba",
    "predict_y_continuous", "run_fixed_point", "sufficient_stats",
]
