"""Numerically-safe PSD linear algebra used throughout the GP core.

All solves against kernel matrices go through a jittered Cholesky so the
ELBO stays finite when the inducing points collapse during optimization.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

# Sentinel: pick jitter by dtype.  f32 needs a much larger nugget than f64 —
# the whitened-solve error grows with cond(Kbb), and 1e-4 relative jitter
# bounds the condition number enough for f32 triangular solves (measured in
# test_model_fit; see DESIGN.md numerical notes).
DEFAULT_JITTER: float | None = None
_JITTER_BY_DTYPE = {"float64": 1e-10, "float32": 1e-4}


def resolve_jitter(jitter: float | None, dtype) -> float:
    if jitter is not None:
        return jitter
    return _JITTER_BY_DTYPE.get(jnp.dtype(dtype).name, 1e-4)


def add_jitter(mat: jax.Array, jitter: float | None = DEFAULT_JITTER) -> jax.Array:
    """Add scaled jitter to the diagonal of a square matrix."""
    n = mat.shape[-1]
    jitter = resolve_jitter(jitter, mat.dtype)
    scale = jnp.maximum(jnp.mean(jnp.diagonal(mat, axis1=-2, axis2=-1)), 1.0)
    return mat + (jitter * scale) * jnp.eye(n, dtype=mat.dtype)


def safe_cholesky(mat: jax.Array, jitter: float | None = DEFAULT_JITTER) -> jax.Array:
    """Cholesky of a PSD matrix with diagonal jitter."""
    return jnp.linalg.cholesky(add_jitter(mat, jitter))


def chol_logdet(chol: jax.Array) -> jax.Array:
    """log|A| from the Cholesky factor of A."""
    return 2.0 * jnp.sum(jnp.log(jnp.diagonal(chol, axis1=-2, axis2=-1)), axis=-1)


def chol_solve(chol: jax.Array, rhs: jax.Array) -> jax.Array:
    """Solve A x = rhs given chol(A) (lower)."""
    y = jax.scipy.linalg.solve_triangular(chol, rhs, lower=True)
    return jax.scipy.linalg.solve_triangular(chol.T, y, lower=False)


def psd_solve(mat: jax.Array, rhs: jax.Array, jitter: float = DEFAULT_JITTER) -> jax.Array:
    return chol_solve(safe_cholesky(mat, jitter), rhs)


def psd_logdet(mat: jax.Array, jitter: float = DEFAULT_JITTER) -> jax.Array:
    return chol_logdet(safe_cholesky(mat, jitter))


def whiten(chol: jax.Array, mat: jax.Array) -> jax.Array:
    """L^{-1} M L^{-T} for symmetric M, given L = chol(A).

    The whitened form I + beta * whiten(L, A1) is the numerically safe way to
    factor Kbb + beta A1: its Cholesky has diagonal >= 1 regardless of beta,
    where the direct factorization fails in f32 once beta gets large.
    """
    half = jax.scipy.linalg.solve_triangular(chol, mat, lower=True)
    out = jax.scipy.linalg.solve_triangular(chol, half.T, lower=True)
    return 0.5 * (out + out.T)  # re-symmetrize f32 roundoff


def whiten_vec(chol: jax.Array, vec: jax.Array) -> jax.Array:
    """L^{-1} v."""
    return jax.scipy.linalg.solve_triangular(chol, vec, lower=True)


def trace_solve(chol: jax.Array, mat: jax.Array) -> jax.Array:
    """tr(A^{-1} M) given chol(A)."""
    return jnp.trace(chol_solve(chol, mat))


def quad_form_solve(chol: jax.Array, vec: jax.Array) -> jax.Array:
    """v^T A^{-1} v given chol(A)."""
    w = jax.scipy.linalg.solve_triangular(chol, vec, lower=True)
    return jnp.sum(w * w)


def triangular_inverse(chol: jax.Array) -> jax.Array:
    """Explicit L^{-1} for a lower-triangular L.

    Used to whiten kernel FEATURES inside the statistics pass:
    phi = k(x, B) L^{-T} is a plain matmul (MXU-friendly, fuses into the
    Pallas gram kernel) and makes the whitened gram sum_j phi phi^T PSD **by
    construction** in any precision — whitening the summed A1 afterwards is
    not (f32 roundoff scales with cond(Kbb) and beta).  With the default
    relative jitter, cond(L) <= ~1e2, so the explicit inverse is safe.
    """
    eye = jnp.eye(chol.shape[-1], dtype=chol.dtype)
    return jax.scipy.linalg.solve_triangular(chol, eye, lower=True)
