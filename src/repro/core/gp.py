"""Covariance (kernel) functions over concatenated latent-factor inputs.

The paper's model places a GP prior over f(x_i) where
``x_i = [u^{(1)}_{i_1}, ..., u^{(K)}_{i_K}]`` is the concatenation of one
latent-factor row per tensor mode.  Because the covariance is an ordinary
vector kernel on these concatenations (NOT a Kronecker product over modes),
any subset of tensor entries may be used for training.

Every kernel is parameterized by a :class:`KernelParams` pytree with
unconstrained (log-space) parameters so they can be optimized jointly with
the latent factors, as in the paper ("kernel parameters were estimated
jointly with the latent factors").

Supported kinds (paper cross-validates RBF / ARD / Matern): ``rbf``, ``ard``,
``matern32``, ``matern52``, ``linear``.
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp

KERNEL_KINDS = ("rbf", "ard", "matern32", "matern52", "linear")


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class KernelParams:
    """Unconstrained kernel hyper-parameters.

    log_lengthscale: shape [D] for ARD kernels, shape [] for isotropic.
    log_amplitude:   scalar, k = amp^2 * corr(...).
    """

    log_lengthscale: jax.Array
    log_amplitude: jax.Array

    @property
    def lengthscale(self) -> jax.Array:
        return jnp.exp(self.log_lengthscale)

    @property
    def amplitude2(self) -> jax.Array:
        return jnp.exp(2.0 * self.log_amplitude)


def init_kernel_params(
    kind: str, input_dim: int, lengthscale: float = 1.0, amplitude: float = 1.0,
    dtype=jnp.float32,
) -> KernelParams:
    if kind not in KERNEL_KINDS:
        raise ValueError(f"unknown kernel kind {kind!r}; pick from {KERNEL_KINDS}")
    if kind in ("ard",):
        log_ls = jnp.full((input_dim,), jnp.log(lengthscale), dtype=dtype)
    else:
        log_ls = jnp.asarray(jnp.log(lengthscale), dtype=dtype)
    return KernelParams(
        log_lengthscale=log_ls,
        log_amplitude=jnp.asarray(jnp.log(amplitude), dtype=dtype),
    )


def _scaled(params: KernelParams, x: jax.Array) -> jax.Array:
    return x / params.lengthscale


def _sqdist(xs: jax.Array, zs: jax.Array) -> jax.Array:
    """Pairwise squared distances, numerically clamped at 0.

    xs: [N, D], zs: [M, D] -> [N, M].
    """
    x2 = jnp.sum(xs * xs, axis=-1)[:, None]
    z2 = jnp.sum(zs * zs, axis=-1)[None, :]
    cross = xs @ zs.T
    return jnp.maximum(x2 + z2 - 2.0 * cross, 0.0)


def _corr(kind: str, r2: jax.Array) -> jax.Array:
    """Correlation as a function of the scaled squared distance."""
    if kind in ("rbf", "ard"):
        return jnp.exp(-0.5 * r2)
    r = jnp.sqrt(r2 + 1e-12)
    if kind == "matern32":
        s = jnp.sqrt(3.0) * r
        return (1.0 + s) * jnp.exp(-s)
    if kind == "matern52":
        s = jnp.sqrt(5.0) * r
        return (1.0 + s + s * s / 3.0) * jnp.exp(-s)
    raise ValueError(f"unknown stationary kernel {kind!r}")


def kernel_matrix(kind: str, params: KernelParams, xs: jax.Array, zs: jax.Array) -> jax.Array:
    """Cross-covariance k(xs, zs): [N, D] x [M, D] -> [N, M]."""
    if kind == "linear":
        return params.amplitude2 * (_scaled(params, xs) @ _scaled(params, zs).T)
    r2 = _sqdist(_scaled(params, xs), _scaled(params, zs))
    return params.amplitude2 * _corr(kind, r2)


def kernel_diag(kind: str, params: KernelParams, xs: jax.Array) -> jax.Array:
    """Diagonal k(x_i, x_i): [N, D] -> [N]."""
    if kind == "linear":
        s = _scaled(params, xs)
        return params.amplitude2 * jnp.sum(s * s, axis=-1)
    return jnp.full(xs.shape[:-1], params.amplitude2, dtype=xs.dtype) * jnp.ones(
        (), dtype=xs.dtype
    )


def kernel_fn(kind: str) -> Callable[[KernelParams, jax.Array, jax.Array], jax.Array]:
    def fn(params, xs, zs):
        return kernel_matrix(kind, params, xs, zs)

    return fn


def gather_inputs(factors: tuple[jax.Array, ...], idx: jax.Array) -> jax.Array:
    """Build GP inputs x_i by concatenating latent-factor rows.

    factors: per-mode latent matrices U^{(k)} of shape [d_k, r_k].
    idx:     [N, K] integer entry indices.
    returns: [N, sum_k r_k].
    """
    parts = [factors[k][idx[:, k]] for k in range(len(factors))]
    return jnp.concatenate(parts, axis=-1)
