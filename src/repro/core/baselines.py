"""Baselines the paper compares against, implemented in JAX.

  * CP        -- multilinear CANDECOMP/PARAFAC on observed entries (gradient
                 trained).  "CP-2" in the paper is this model trained on the
                 same balanced zero/nonzero entry set as ours — a data choice,
                 not a model change.
  * Tucker    -- core tensor + per-mode factors, entrywise contraction.
  * InfTucker -- the Kronecker tensor-variate GP (Xu et al., 2012) at small
                 scale: exact marginal likelihood via per-mode eigendecomp of
                 the mode covariances (the Kronecker structure the paper's
                 model deliberately removes).  Continuous likelihood.
  * Logistic regression / linear SVM -- the CTR baselines (§6.4): each entry
                 is the concatenation of one-hot mode indicators, so a linear
                 model is one scalar weight per (mode, index) plus bias.
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro import optim
from repro.data.tensor_store import EntrySet


# ------------------------------------------------------------------- CP ----


@dataclasses.dataclass
class CPModel:
    factors: tuple[jax.Array, ...]

    def score(self, idx: jax.Array) -> jax.Array:
        prod = jnp.ones((idx.shape[0], self.factors[0].shape[1]))
        for k, u in enumerate(self.factors):
            prod = prod * u[idx[:, k]]
        return jnp.sum(prod, axis=-1)


def fit_cp(
    train: EntrySet,
    dims: tuple[int, ...],
    rank: int = 3,
    binary: bool = False,
    steps: int = 500,
    lr: float = 5e-2,
    l2: float = 1e-3,
    seed: int = 0,
) -> CPModel:
    key = jax.random.PRNGKey(seed)
    factors = tuple(
        0.3 * jax.random.normal(jax.random.fold_in(key, k), (dims[k], rank))
        for k in range(len(dims))
    )
    idx = jnp.asarray(train.idx)
    y = jnp.asarray(train.y)

    def loss(factors):
        model = CPModel(factors)
        s = model.score(idx)
        if binary:
            data = jnp.mean(jnp.logaddexp(0.0, -(2 * y - 1) * s))
        else:
            data = jnp.mean((s - y) ** 2)
        reg = sum(jnp.sum(u * u) for u in factors)
        return data + l2 * reg

    opt = optim.adam(lr)
    state = opt.init(factors)

    @jax.jit
    def step(factors, state):
        g = jax.grad(loss)(factors)
        upd, state = opt.update(g, state, factors)
        return optim.apply_updates(factors, upd), state

    for _ in range(steps):
        factors, state = step(factors, state)
    return CPModel(factors)


# --------------------------------------------------------------- Tucker ----


@dataclasses.dataclass
class TuckerModel:
    core: jax.Array  # [r1, ..., rK]
    factors: tuple[jax.Array, ...]

    def score(self, idx: jax.Array) -> jax.Array:
        rows = [u[idx[:, k]] for k, u in enumerate(self.factors)]  # [N, r_k]
        out = jnp.broadcast_to(self.core[None], (idx.shape[0],) + self.core.shape)
        for r in rows:
            # contract the leading core mode with that mode's factor row
            out = jnp.einsum("nr..., nr -> n...", out, r)
        return out


def fit_tucker(
    train: EntrySet,
    dims: tuple[int, ...],
    rank: int = 3,
    binary: bool = False,
    steps: int = 500,
    lr: float = 5e-2,
    l2: float = 1e-3,
    seed: int = 0,
) -> TuckerModel:
    key = jax.random.PRNGKey(seed)
    k_mode = len(dims)
    core = 0.3 * jax.random.normal(jax.random.fold_in(key, 99), (rank,) * k_mode)
    factors = tuple(
        0.3 * jax.random.normal(jax.random.fold_in(key, k), (dims[k], rank))
        for k in range(k_mode)
    )
    idx = jnp.asarray(train.idx)
    y = jnp.asarray(train.y)

    def loss(params):
        core, factors = params
        s = TuckerModel(core, factors).score(idx)
        if binary:
            data = jnp.mean(jnp.logaddexp(0.0, -(2 * y - 1) * s))
        else:
            data = jnp.mean((s - y) ** 2)
        reg = jnp.sum(core * core) + sum(jnp.sum(u * u) for u in factors)
        return data + l2 * reg

    opt = optim.adam(lr)
    params = (core, factors)
    state = opt.init(params)

    @jax.jit
    def step(params, state):
        g = jax.grad(loss)(params)
        upd, state = opt.update(g, state, params)
        return optim.apply_updates(params, upd), state

    for _ in range(steps):
        params, state = step(params, state)
    return TuckerModel(*params)


# ------------------------------------------------------------ InfTucker ----


def _mode_cov(u: jax.Array, log_ls: jax.Array, log_amp: jax.Array) -> jax.Array:
    x = u / jnp.exp(log_ls)
    sq = jnp.sum(x * x, -1)
    d2 = jnp.maximum(sq[:, None] + sq[None, :] - 2 * x @ x.T, 0.0)
    return jnp.exp(2 * log_amp) * jnp.exp(-0.5 * d2)


@dataclasses.dataclass
class InfTuckerModel:
    factors: tuple[jax.Array, ...]
    log_ls: jax.Array
    log_amp: jax.Array
    log_noise: jax.Array
    # cached posterior for prediction
    alpha: np.ndarray | None = None  # [prod(d)] solve of (K + s2 I)^-1 y
    eigvecs: tuple[np.ndarray, ...] | None = None
    eigvals: tuple[np.ndarray, ...] | None = None


def fit_inftucker(
    tensor_dense: np.ndarray,
    rank: int = 3,
    steps: int = 150,
    lr: float = 5e-2,
    seed: int = 0,
) -> InfTuckerModel:
    """Exact TGP marginal likelihood on a SMALL dense tensor.

    log N(vec(Y); 0, kron_k K_k + s2 I) with per-mode eigendecompositions:
    eigenvalues of the Kronecker product are outer products of the per-mode
    eigenvalues, so logdet and the quadratic form are O(sum d_k^3 + prod d_k).
    This only scales to small tensors — which is the paper's point.
    """
    dims = tensor_dense.shape
    key = jax.random.PRNGKey(seed)
    factors = tuple(
        0.3 * jax.random.normal(jax.random.fold_in(key, k), (dims[k], rank))
        for k in range(len(dims))
    )
    y = jnp.asarray(tensor_dense.reshape(-1))
    params0 = {
        "factors": factors,
        "log_ls": jnp.zeros(()),
        "log_amp": jnp.zeros(()),
        "log_noise": jnp.asarray(-1.0),
    }

    def neg_mll(params):
        covs = [
            _mode_cov(u, params["log_ls"], params["log_amp"]) for u in params["factors"]
        ]
        eigs = [jnp.linalg.eigh(c + 1e-6 * jnp.eye(c.shape[0])) for c in covs]
        lam = jnp.ones(())
        # kron eigenvalues via outer products, flattened progressively
        kron_eval = jnp.ones((1,))
        for w, _ in eigs:
            kron_eval = (kron_eval[:, None] * w[None, :]).reshape(-1)
        s2 = jnp.exp(2 * params["log_noise"])
        denom = kron_eval + s2
        # rotate y into the kron eigenbasis: sequential mode products
        yt = y.reshape(dims)
        for k, (_, q) in enumerate(eigs):
            yt = jnp.moveaxis(jnp.tensordot(q.T, jnp.moveaxis(yt, k, 0), axes=1), 0, k)
        quad = jnp.sum((yt.reshape(-1) ** 2) / denom)
        logdet = jnp.sum(jnp.log(denom))
        prior = sum(jnp.sum(u * u) for u in params["factors"])
        return 0.5 * (logdet + quad) + 0.5 * prior

    opt = optim.adam(lr)
    state = opt.init(params0)

    @jax.jit
    def step(params, state):
        g = jax.grad(neg_mll)(params)
        upd, state = opt.update(g, state, params)
        return optim.apply_updates(params, upd), state

    params = params0
    for _ in range(steps):
        params, state = step(params, state)

    # cache posterior pieces for prediction
    covs = [np.asarray(_mode_cov(u, params["log_ls"], params["log_amp"])) for u in params["factors"]]
    eigs = [np.linalg.eigh(c + 1e-6 * np.eye(c.shape[0])) for c in covs]
    kron_eval = np.ones((1,))
    for w, _ in eigs:
        kron_eval = (kron_eval[:, None] * w[None, :]).reshape(-1)
    s2 = float(np.exp(2 * params["log_noise"]))
    yt = np.asarray(tensor_dense)
    for k, (_, q) in enumerate(eigs):
        yt = np.moveaxis(np.tensordot(q.T, np.moveaxis(yt, k, 0), axes=1), 0, k)
    alpha_t = yt.reshape(-1) / (kron_eval + s2)
    # rotate back
    at = alpha_t.reshape(dims)
    for k, (_, q) in enumerate(eigs):
        at = np.moveaxis(np.tensordot(q, np.moveaxis(at, k, 0), axes=1), 0, k)
    model = InfTuckerModel(
        factors=tuple(params["factors"]),
        log_ls=params["log_ls"],
        log_amp=params["log_amp"],
        log_noise=params["log_noise"],
        alpha=at.reshape(-1),
    )
    return model


def inftucker_predict(model: InfTuckerModel, dims: tuple[int, ...], idx: np.ndarray) -> np.ndarray:
    """Posterior mean at entries: K_*,all alpha.  K rows via Kronecker products."""
    covs = [np.asarray(_mode_cov(u, model.log_ls, model.log_amp)) for u in model.factors]
    alpha = model.alpha.reshape(dims)
    out = np.zeros(idx.shape[0])
    for n in range(idx.shape[0]):
        v = alpha
        for k in range(len(dims)):
            row = covs[k][idx[n, k]]  # [d_k]
            v = np.tensordot(row, v, axes=([0], [0]))
        out[n] = v
    return out


# --------------------------------------------- linear CTR baselines --------


@dataclasses.dataclass
class LinearPerModeModel:
    weights: tuple[jax.Array, ...]  # one scalar per (mode, index)
    bias: jax.Array

    def score(self, idx: jax.Array) -> jax.Array:
        s = self.bias
        for k, wk in enumerate(self.weights):
            s = s + wk[idx[:, k]]
        return s


def fit_linear(
    train: EntrySet,
    dims: tuple[int, ...],
    loss_kind: str = "logistic",  # "logistic" | "hinge"
    steps: int = 400,
    lr: float = 5e-2,
    l2: float = 1e-4,
    seed: int = 0,
) -> LinearPerModeModel:
    key = jax.random.PRNGKey(seed)
    weights = tuple(
        0.01 * jax.random.normal(jax.random.fold_in(key, k), (dims[k],))
        for k in range(len(dims))
    )
    bias = jnp.zeros(())
    idx = jnp.asarray(train.idx)
    sign = jnp.asarray(2 * train.y - 1)

    def loss(params):
        w, b = params
        s = LinearPerModeModel(w, b).score(idx)
        if loss_kind == "logistic":
            data = jnp.mean(jnp.logaddexp(0.0, -sign * s))
        else:
            data = jnp.mean(jnp.maximum(0.0, 1.0 - sign * s))
        return data + l2 * sum(jnp.sum(x * x) for x in w)

    opt = optim.adam(lr)
    params = (weights, bias)
    state = opt.init(params)

    @jax.jit
    def step(params, state):
        g = jax.grad(loss)(params)
        upd, state = opt.update(g, state, params)
        return optim.apply_updates(params, upd), state

    for _ in range(steps):
        params, state = step(params, state)
    return LinearPerModeModel(*params)
