"""Fixed-point iteration for the binary bound's lambda (Eq. 8 / Lemma 4.3).

    lam^{t+1} = (Kbb + A1)^{-1} (A1 lam^t + a5(lam^t))

Each iteration is one pass of additive statistics (a5 depends on lam) — i.e.
one key-value-free MapReduce round in the paper, one psum'd shard_map pass
here.  Lemma 4.3 guarantees monotone improvement of L2* and convergence.
"""
from __future__ import annotations

from functools import partial
from typing import Callable

import jax
import jax.numpy as jnp

from repro.core import gp, linalg
from repro.core.elbo import DFNTFParams


def lam_step(
    kind: str,
    params: DFNTFParams,
    a1: jax.Array,
    a5: jax.Array,
    jitter: float = linalg.DEFAULT_JITTER,
) -> jax.Array:
    """One fixed-point update given the current statistics.

    (Kbb + A1)^{-1} r solved in whitened form L^{-T} M^{-1} L^{-1} r with
    M = I + L^{-1} A1 L^{-T} (robust in f32; see core/elbo.py).
    """
    kbb = gp.kernel_matrix(kind, params.kernel, params.inducing, params.inducing)
    chol_kbb = linalg.safe_cholesky(kbb, jitter)
    p = kbb.shape[0]
    m = jnp.eye(p, dtype=kbb.dtype) + linalg.whiten(chol_kbb, a1)
    chol_m = linalg.safe_cholesky(m, jitter)
    rw = linalg.whiten_vec(chol_kbb, a1 @ params.lam + a5)
    return jax.scipy.linalg.solve_triangular(
        chol_kbb.T, linalg.chol_solve(chol_m, rw), lower=False
    )


@partial(jax.jit, static_argnames=("kind", "stats_fn", "max_iters"))
def run_fixed_point(
    kind: str,
    params: DFNTFParams,
    stats_fn: Callable[[DFNTFParams], tuple[jax.Array, jax.Array]],
    max_iters: int = 20,
    tol: float = 1e-5,
) -> tuple[DFNTFParams, jax.Array]:
    """Iterate lambda to (near) convergence.

    stats_fn(params) -> (A1, a5) must recompute a5 under params.lam; it may be
    a sharded (psum) computation.  Returns updated params and the number of
    iterations actually run.
    """

    def cond(state):
        _, delta, it = state
        return jnp.logical_and(delta > tol, it < max_iters)

    def body(state):
        p, _, it = state
        a1, a5 = stats_fn(p)
        new_lam = lam_step(kind, p, a1, a5)
        delta = jnp.max(jnp.abs(new_lam - p.lam))
        return dataclass_replace_lam(p, new_lam), delta, it + 1

    init = (params, jnp.asarray(jnp.inf, params.lam.dtype), jnp.asarray(0, jnp.int32))
    final, _, iters = jax.lax.while_loop(cond, body, init)
    return final, iters


def dataclass_replace_lam(params: DFNTFParams, lam: jax.Array) -> DFNTFParams:
    import dataclasses

    return dataclasses.replace(params, lam=lam)
