"""Distributed inference: the key-value-free MapReduce on TPU meshes.

Paper §4.3.2: each mapper computes FULL fixed-size statistics/gradients from
its shard of tensor entries and the reducer SUMS them — no key-value shuffle.
The exact TPU-native analogue:

    map    = shard_map over the mesh's data axes (each device owns a slice of
             the entry batch and computes SuffStats from it),
    reduce = lax.psum of the statistics over those axes (a ring all-reduce —
             the only collective the algorithm needs).

Gradients w.r.t. the replicated parameters flow through the shard_map
transpose, which inserts exactly one more psum — i.e. the gradient
aggregation is ALSO key-value-free, matching the paper's design where each
mapper emits a full gradient vector.

Numerics: the production path computes WHITENED statistics (phi = L^{-1} k
applied inside the per-shard pass; see core/stats.py and core/elbo.py) so the
p x p factorization stays finite in f32 at any learned noise precision.  The
whitening operator L^{-1} is built from the replicated parameters, identically
on every shard — no extra communication.

The entry batch must be equally divisible over the sharded axes; callers pad
with zero-weight entries (repro.data.loader).
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

from repro.core import elbo as elbo_mod
from repro.core import stats as stats_mod
from repro.core.elbo import DFNTFParams


@dataclasses.dataclass(frozen=True)
class InferenceConfig:
    kernel_kind: str = "ard"
    task: str = "continuous"  # "continuous" | "binary"
    chunk: int | None = None  # microbatch size per device (lax.scan)
    backend: str = "jnp"  # "jnp" | "pallas"
    data_axes: tuple[str, ...] = ("data",)  # mesh axes the batch is sharded over


def _psum(tree, axes):
    return jax.tree.map(lambda x: jax.lax.psum(x, axes), tree)


def _shard(fn, mesh: Mesh | None, cfg: InferenceConfig, n_batch_args: int):
    """Wrap fn(params, *batch) in shard_map with batch args data-sharded."""
    if mesh is None:
        return fn
    spec = P(cfg.data_axes)
    in_specs = (P(),) + (spec,) * n_batch_args
    return jax.shard_map(fn, mesh=mesh, in_specs=in_specs, out_specs=P())


def make_elbo_fn(
    cfg: InferenceConfig, mesh: Mesh | None = None
) -> Callable[[DFNTFParams, jax.Array, jax.Array, jax.Array], jax.Array]:
    """Build elbo(params, idx, y, w) -> scalar, optionally mesh-distributed.

    With a mesh, the batch is sharded over cfg.data_axes and statistics are
    psum'd (the key-value-free reduce); without one, plain local computation.
    The returned value is the FULL-DATA tight ELBO either way — sharded and
    unsharded results agree (test_distributed.py).
    """
    axes = cfg.data_axes

    def local(params, idx, y, w):
        chol_kbb, linv = elbo_mod.whiten_operator(cfg.kernel_kind, params)
        if cfg.task == "continuous":
            wstats = stats_mod.sufficient_stats(
                cfg.kernel_kind, params.kernel, params.factors, params.inducing,
                idx, y, w, linv, chunk=cfg.chunk, backend=cfg.backend,
            )
            if mesh is not None:
                wstats = _psum(wstats, axes)
            return elbo_mod.elbo_continuous_whitened(params, wstats)
        lam_w = chol_kbb.T @ jax.lax.stop_gradient(params.lam)
        wstats, s_phi, _a5w = stats_mod.binary_stats(
            cfg.kernel_kind, params.kernel, params.factors, params.inducing,
            idx, y, lam_w, w, linv, chunk=cfg.chunk, backend=cfg.backend,
        )
        if mesh is not None:
            wstats, s_phi = _psum((wstats, s_phi), axes)
        return elbo_mod.elbo_binary_whitened(params, wstats, s_phi, lam_w)

    return jax.jit(_shard(local, mesh, cfg, n_batch_args=3))


def make_loss_and_grad(cfg: InferenceConfig, mesh: Mesh | None = None):
    """negative-ELBO value_and_grad, jitted; the trainer's inner step."""
    elbo_fn = make_elbo_fn(cfg, mesh)

    def loss(params, idx, y, w):
        return -elbo_fn(params, idx, y, w)

    return jax.jit(jax.value_and_grad(loss))


def make_lambda_update(cfg: InferenceConfig, mesh: Mesh | None = None):
    """One distributed fixed-point update of lambda (Eq. 8).

    Statistics (A1w, a5w) are computed shard-locally and psum'd; the p x p
    solve is replicated (p ~ 100, negligible) — exactly the paper's layout
    where the reducer finishes the tiny dense algebra.
    """
    axes = cfg.data_axes

    def stats(params, lam_w, linv, idx, y, w):
        wstats, _s_phi, a5w = stats_mod.binary_stats(
            cfg.kernel_kind, params.kernel, params.factors, params.inducing,
            idx, y, lam_w, w, linv, chunk=cfg.chunk, backend=cfg.backend,
        )
        out = (wstats.a1, a5w)
        return _psum(out, axes) if mesh is not None else out

    if mesh is not None:
        spec = P(cfg.data_axes)
        stats = jax.shard_map(
            stats, mesh=mesh,
            in_specs=(P(), P(), P(), spec, spec, spec), out_specs=P(),
        )

    @jax.jit
    def update(params: DFNTFParams, idx, y, w) -> DFNTFParams:
        chol_kbb, linv = elbo_mod.whiten_operator(cfg.kernel_kind, params)
        lam_w = chol_kbb.T @ params.lam
        a1w, a5w = stats(params, lam_w, linv, idx, y, w)
        new_lam_w = elbo_mod.lam_step_whitened(a1w, a5w, lam_w)
        # back to the raw basis: lam = L^{-T} lam_w
        new_lam = jax.scipy.linalg.solve_triangular(
            chol_kbb.T, new_lam_w, lower=False
        )
        return dataclasses.replace(params, lam=new_lam)

    return update


def make_stats_fn(cfg: InferenceConfig, mesh: Mesh | None = None):
    """Global WHITENED SuffStats + chol(Kbb) — builds prediction caches."""
    axes = cfg.data_axes

    def stats(params, linv, idx, y, w):
        out = stats_mod.sufficient_stats(
            cfg.kernel_kind, params.kernel, params.factors, params.inducing,
            idx, y, w, linv, chunk=cfg.chunk, backend=cfg.backend,
        )
        return _psum(out, axes) if mesh is not None else out

    if mesh is not None:
        spec = P(cfg.data_axes)
        stats = jax.shard_map(
            stats, mesh=mesh, in_specs=(P(), P(), spec, spec, spec), out_specs=P(),
        )

    @jax.jit
    def run(params, idx, y, w):
        chol_kbb, linv = elbo_mod.whiten_operator(cfg.kernel_kind, params)
        return stats(params, linv, idx, y, w), chol_kbb

    return run


def shard_batch(mesh: Mesh, cfg: InferenceConfig, idx, y, w):
    """Place a host batch with the entry dimension sharded over the data axes."""
    spec = P(cfg.data_axes)
    dev = lambda a: jax.device_put(a, NamedSharding(mesh, spec))
    return dev(idx), dev(y), dev(w)
