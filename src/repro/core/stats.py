"""Additive sufficient statistics of the tight ELBOs (Theorems 4.1 / 4.2).

Everything the bounds need from the data is a small, fixed-size sum over
tensor entries:

    A1 = sum_j w_j k(B, x_j) k(x_j, B)        [p, p]
    a2 = sum_j w_j y_j^2                      []      (continuous only)
    a3 = sum_j w_j k(x_j, x_j)                []
    a4 = sum_j w_j k(B, x_j) y_j              [p]     (continuous only)
    n  = sum_j w_j                            []

This additivity IS the paper's separability argument: each mapper owns a
shard of entries, computes the same fixed-size statistics, and the reducer
just sums them (key-value-free MapReduce).  On TPU the "reducer" is a psum
over the mesh's data axes (see core/inference.py).

``w_j`` is an entry weight: 0 for padding (shards must be equal-sized under
shard_map), arbitrary positive values for importance weighting of e.g.
balanced zero/nonzero samples.  With w == 1 this is exactly the paper.

Two interchangeable backends compute the same statistics:
  * "jnp"    -- materializes K_SB per chunk (reference; always available)
  * "pallas" -- fused Pallas TPU kernel, never materializes K_SB in HBM
                (see repro/kernels/gp_gram)
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp

from repro.core import gp


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class SuffStats:
    """Additive sufficient statistics; a monoid under elementwise +."""

    a1: jax.Array  # [p, p]
    a2: jax.Array  # []
    a3: jax.Array  # []
    a4: jax.Array  # [p]
    n: jax.Array  # [] effective number of entries (sum of weights)

    def __add__(self, other: "SuffStats") -> "SuffStats":
        return jax.tree.map(jnp.add, self, other)

    @staticmethod
    def zero(p: int, dtype=jnp.float32) -> "SuffStats":
        z = jnp.zeros((), dtype)
        return SuffStats(jnp.zeros((p, p), dtype), z, z, jnp.zeros((p,), dtype), z)


def _features(kind, kp, xs, bs, whiten_inv):
    """k(x, B), optionally whitened: phi = k(x, B) L^{-T}.

    With whitening the gram sum_j w_j phi_j phi_j^T is PSD by construction,
    which keeps chol(I + beta * gram) finite in f32 at any learned beta.
    """
    kxb = gp.kernel_matrix(kind, kp, xs, bs)  # [n, p]
    if whiten_inv is not None:
        kxb = kxb @ whiten_inv.T
    return kxb


def _chunk_stats_jnp(kind, kp, xs, bs, y, w, whiten_inv) -> SuffStats:
    kxb = _features(kind, kp, xs, bs, whiten_inv)
    kxb_w = kxb * w[:, None]
    a1 = kxb.T @ kxb_w
    a2 = jnp.sum(w * y * y)
    a3 = jnp.sum(w * gp.kernel_diag(kind, kp, xs))
    a4 = kxb_w.T @ y
    return SuffStats(a1, a2, a3, a4, jnp.sum(w))


def _chunk_stats(backend, kind, kp, xs, bs, y, w, whiten_inv) -> SuffStats:
    if backend == "pallas":
        # Imported lazily: the kernels package depends on this module's
        # SuffStats container for its output pytree.
        from repro.kernels.gp_gram import ops as gp_gram_ops

        return gp_gram_ops.gram_stats(kind, kp, xs, bs, y, w, whiten_inv)
    return _chunk_stats_jnp(kind, kp, xs, bs, y, w, whiten_inv)


@partial(jax.jit, static_argnames=("kind", "chunk", "backend"))
def sufficient_stats(
    kind: str,
    kp: gp.KernelParams,
    factors: tuple[jax.Array, ...],
    inducing: jax.Array,
    idx: jax.Array,
    y: jax.Array,
    w: jax.Array | None = None,
    whiten_inv: jax.Array | None = None,
    *,
    chunk: int | None = None,
    backend: str = "jnp",
) -> SuffStats:
    """Compute SuffStats for a set of tensor entries.

    idx: [N, K] per-entry mode indices;  y: [N] observed values;
    w:   [N] weights (None -> ones).
    whiten_inv: optional L^{-1} (L = chol(Kbb)); if given, a1/a4 are the
           WHITENED statistics sum w phi phi^T / sum w phi y, phi = L^-1 k.
    chunk: if set, scan over length-`chunk` microbatches (bounds peak memory
           to O(chunk * p) instead of O(N * p)).  N must be divisible.
    """
    if w is None:
        w = jnp.ones_like(y)
    n = idx.shape[0]
    if chunk is None or chunk >= n:
        xs = gp.gather_inputs(factors, idx)
        return _chunk_stats(backend, kind, kp, xs, inducing, y, w, whiten_inv)

    if n % chunk != 0:
        raise ValueError(f"N={n} not divisible by chunk={chunk}")

    def body(acc: SuffStats, args) -> tuple[SuffStats, None]:
        idx_c, y_c, w_c = args
        xs_c = gp.gather_inputs(factors, idx_c)
        return acc + _chunk_stats(backend, kind, kp, xs_c, inducing, y_c, w_c, whiten_inv), None

    reshape = lambda a: a.reshape((n // chunk, chunk) + a.shape[1:])
    init = SuffStats.zero(inducing.shape[0], dtype=inducing.dtype)
    acc, _ = jax.lax.scan(body, init, (reshape(idx), reshape(y), reshape(w)))
    return acc


@partial(jax.jit, static_argnames=("kind", "chunk", "backend"))
def binary_stats(
    kind: str,
    kp: gp.KernelParams,
    factors: tuple[jax.Array, ...],
    inducing: jax.Array,
    idx: jax.Array,
    y: jax.Array,
    lam: jax.Array,
    w: jax.Array | None = None,
    whiten_inv: jax.Array | None = None,
    *,
    chunk: int | None = None,
    backend: str = "jnp",
) -> tuple[SuffStats, jax.Array, jax.Array]:
    """Statistics for the binary bound: (SuffStats, s_phi, a5).

    s_phi = sum_j w_j log Phi((2 y_j - 1) lam^T k(B, x_j))     []
    a5    = sum_j w_j k(B,x_j) (2y_j-1) N(k^T lam)/Phi((2y_j-1) k^T lam)  [p]

    a5 drives the fixed-point iteration (Eq. 8); s_phi enters L2* (Thm 4.2).
    The a2/a4 slots of SuffStats are computed against y in {0,1}; the binary
    bound does not read them.

    With whiten_inv, features are whitened (phi = L^-1 k) and ``lam`` must be
    given in the whitened basis, lam_w = L^T lam (then lam^T k == lam_w^T phi
    and a5 comes back whitened: a5_w = L^-1 a5).
    """
    if w is None:
        w = jnp.ones_like(y)

    def chunk_fn(idx_c, y_c, w_c):
        xs_c = gp.gather_inputs(factors, idx_c)
        base = _chunk_stats(backend, kind, kp, xs_c, inducing, y_c, w_c, whiten_inv)
        kxb = _features(kind, kp, xs_c, inducing, whiten_inv)  # [n, p]
        sgn = 2.0 * y_c - 1.0
        t = sgn * (kxb @ lam)
        log_phi = jax.scipy.stats.norm.logcdf(t)
        s_phi = jnp.sum(w_c * log_phi)
        # N(t;0,1)/Phi(t) == exp(logpdf - logcdf), the inverse Mills ratio.
        mills = jnp.exp(jax.scipy.stats.norm.logpdf(t) - log_phi)
        a5 = kxb.T @ (w_c * sgn * mills)
        return base, s_phi, a5

    n = idx.shape[0]
    if chunk is None or chunk >= n:
        return chunk_fn(idx, y, w)
    if n % chunk != 0:
        raise ValueError(f"N={n} not divisible by chunk={chunk}")

    def body(acc, args):
        base, s_phi, a5 = chunk_fn(*args)
        acc_base, acc_phi, acc_a5 = acc
        return (acc_base + base, acc_phi + s_phi, acc_a5 + a5), None

    reshape = lambda a: a.reshape((n // chunk, chunk) + a.shape[1:])
    p = inducing.shape[0]
    init = (
        SuffStats.zero(p, dtype=inducing.dtype),
        jnp.zeros((), inducing.dtype),
        jnp.zeros((p,), inducing.dtype),
    )
    acc, _ = jax.lax.scan(body, init, (reshape(idx), reshape(y), reshape(w)))
    return acc
