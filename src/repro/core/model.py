"""High-level DFNTF trainer: fit / predict over entry sets.

Mirrors the paper's optimization procedure (§4.3.1):
  * continuous: gradient-based optimization (Adam / GD / L-BFGS) of -L1*.
  * binary: inner fixed-point loop on lambda (Eq. 8), outer gradient steps on
    (U, B, kernel params) of -L2* — "before we calculate the gradients with
    respect to U and B, we first optimize lambda using the fixed point
    iteration".

Works on a single device or a mesh (key-value-free psum aggregation); the two
paths produce identical math (test_distributed.py).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh

from repro import optim
from repro.core import elbo as elbo_mod
from repro.core import inference, predict
from repro.core.elbo import DFNTFParams
from repro.data.loader import pad_to_multiple
from repro.data.tensor_store import EntrySet


@dataclasses.dataclass(frozen=True)
class FitConfig:
    task: str = "continuous"  # "continuous" | "binary"
    kernel_kind: str = "ard"
    rank: int = 3
    num_inducing: int = 100  # paper: p = 100
    optimizer: str = "adam"  # "adam" | "gd" | "lbfgs"
    learning_rate: float = 1e-2
    steps: int = 200  # outer gradient steps (adam/gd)
    lbfgs_max_iters: int = 100
    fixed_point_iters: int = 5  # lambda inner loop per outer step (binary)
    chunk: int | None = None
    backend: str = "jnp"
    factor_scale: float = 0.1
    beta: float = 1.0
    seed: int = 0
    log_every: int = 50


class DFNTF:
    """Flexible GP tensor factorization (the paper's model)."""

    def __init__(self, dims: tuple[int, ...], config: FitConfig, mesh: Mesh | None = None):
        self.dims = tuple(dims)
        self.config = config
        self.mesh = mesh
        self._icfg = inference.InferenceConfig(
            kernel_kind=config.kernel_kind,
            task=config.task,
            chunk=config.chunk,
            backend=config.backend,
        )
        self.params: DFNTFParams = elbo_mod.init_params(
            jax.random.PRNGKey(config.seed),
            self.dims,
            config.rank,
            num_inducing=config.num_inducing,
            kernel_kind=config.kernel_kind,
            factor_scale=config.factor_scale,
            beta=config.beta,
        )
        self._cache: predict.PosteriorCache | None = None
        self._train_batch = None

    # ------------------------------------------------------------------ fit

    def _num_shards(self) -> int:
        return int(np.prod([self.mesh.shape[a] for a in self._icfg.data_axes])) if self.mesh else 1

    def _prepare(self, train: EntrySet):
        batch = pad_to_multiple(train, self._num_shards())
        idx = jnp.asarray(batch.idx)
        y = jnp.asarray(batch.y)
        w = jnp.asarray(batch.w)
        if self.mesh is not None:
            idx, y, w = inference.shard_batch(self.mesh, self._icfg, idx, y, w)
        return idx, y, w

    def fit(self, train: EntrySet, verbose: bool = False) -> dict[str, Any]:
        """Full-batch training as in the paper. Returns a history dict."""
        idx, y, w = self._prepare(train)
        self._train_batch = (idx, y, w)
        cfg = self.config
        if cfg.task == "binary":
            # init inducing points near observed inputs helps the Probit model
            pass
        if cfg.optimizer == "lbfgs":
            history = self._fit_lbfgs(idx, y, w, verbose)
        else:
            history = self._fit_sgd(idx, y, w, verbose)
        self._refresh_cache(idx, y, w)
        return history

    def _fit_sgd(self, idx, y, w, verbose):
        cfg = self.config
        loss_grad = inference.make_loss_and_grad(self._icfg, self.mesh)
        lam_update = (
            inference.make_lambda_update(self._icfg, self.mesh)
            if cfg.task == "binary"
            else None
        )
        opt = (
            optim.adam(cfg.learning_rate)
            if cfg.optimizer == "adam"
            else optim.sgd(cfg.learning_rate, momentum=0.9)
        )
        state = opt.init(self.params)
        history = {"elbo": [], "time": []}
        t0 = time.perf_counter()
        params = self.params
        for step in range(cfg.steps):
            if lam_update is not None:
                for _ in range(cfg.fixed_point_iters):
                    params = lam_update(params, idx, y, w)
            loss, grads = loss_grad(params, idx, y, w)
            updates, state = opt.update(grads, state, params)
            params = optim.apply_updates(params, updates)
            if cfg.task == "binary":
                # lambda is driven by the fixed point, not the gradient
                params = dataclasses.replace(
                    params, lam=jax.lax.stop_gradient(params.lam)
                )
            history["elbo"].append(-float(loss))
            history["time"].append(time.perf_counter() - t0)
            if verbose and step % cfg.log_every == 0:
                print(f"step {step:5d}  elbo {-float(loss):.4f}")
        self.params = params
        return history

    def _fit_lbfgs(self, idx, y, w, verbose):
        cfg = self.config
        elbo_fn = inference.make_elbo_fn(self._icfg, self.mesh)
        lam_update = (
            inference.make_lambda_update(self._icfg, self.mesh)
            if cfg.task == "binary"
            else None
        )
        params = self.params
        history = {"elbo": [], "time": []}
        t0 = time.perf_counter()
        rounds = 5 if cfg.task == "binary" else 1
        iters = max(cfg.lbfgs_max_iters // rounds, 1)
        for _ in range(rounds):
            if lam_update is not None:
                for _ in range(cfg.fixed_point_iters):
                    params = lam_update(params, idx, y, w)
            lam_fixed = params.lam

            def neg_elbo(p):
                p = dataclasses.replace(p, lam=lam_fixed)
                return -elbo_fn(p, idx, y, w)

            res = optim.minimize(neg_elbo, params, max_iters=iters, tol=1e-7)
            params = dataclasses.replace(res.params, lam=lam_fixed)
            history["elbo"].append(-float(res.value))
            history["time"].append(time.perf_counter() - t0)
            if verbose:
                print(f"lbfgs round: elbo {-float(res.value):.4f} iters {int(res.iterations)}")
        self.params = params
        return history

    # -------------------------------------------------------------- predict

    def _refresh_cache(self, idx, y, w):
        stats_fn = inference.make_stats_fn(self._icfg, self.mesh)
        wstats, chol_kbb = stats_fn(self.params, idx, y, w)
        self._cache = predict.build_cache(
            self.config.kernel_kind, self.params, wstats, chol_kbb,
            task=self.config.task,
        )

    def predict(self, idx: np.ndarray) -> np.ndarray:
        """Continuous: posterior mean of y."""
        assert self._cache is not None, "call fit() first"
        mean, _ = predict.predict_y_continuous(
            self.config.kernel_kind, self.params, self._cache, jnp.asarray(idx)
        )
        return np.asarray(mean)

    def predict_proba(self, idx: np.ndarray) -> np.ndarray:
        """Binary: P(y = 1)."""
        assert self._cache is not None, "call fit() first"
        return np.asarray(
            predict.predict_proba(
                self.config.kernel_kind, self.params, self._cache, jnp.asarray(idx)
            )
        )

    def elbo(self) -> float:
        assert self._train_batch is not None
        elbo_fn = inference.make_elbo_fn(self._icfg, self.mesh)
        return float(elbo_fn(self.params, *self._train_batch))
