"""Distributed decode attention (flash-decoding on TPU).

Problem: a decode step writes ONE token into a KV cache that must be sharded
over its sequence dim for the big archs (qwen2-72b's 32k cache is 86GB/device
if seq-replicated over the model axis).  Under plain GSPMD, a
``dynamic_update_slice`` at a traced position into a seq-sharded tensor
triggers "involuntary full rematerialization" — the compiler replicates the
whole cache (seen as multi-GB all-gathers in the dry-run).

Fix — the flash-decoding schedule, expressed with shard_map:
  * each ``model``-axis shard owns a contiguous S/|model| slice of the cache;
  * the new token's k/v is written ONLY by the owner shard (O(1)
    dynamic-update-slice on the local slice; non-owners write back the value
    they already hold at the clamped slot — no-op, no copy);
  * each shard computes attention over its local slice with a local
    (max, sumexp, weighted-V) triple, then the shards combine with one
    log-sum-exp reduction: pmax for the max, psum for the rescaled
    normalizer and values — (B, H)-sized collectives instead of cache-sized.

This is also the paper's separability argument in miniature: the softmax
statistics are ADDITIVE across shards after max-alignment, so the reduce is
a key-value-free psum, never a gather of the cache.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp
from jax import lax, shard_map
from jax.sharding import PartitionSpec as P

from repro.models.layers import NEG_INF

DATA_AXES = ("pod", "data")


def _local_lse_attend(q, k, v, valid):
    """Local partial attention. q:(B,1,H,hd), k/v:(B,Sl,Hk,hd), valid:(B,Sl).
    Returns (m, l, o) f32: running max (B,Hk,g), normalizer, weighted values
    (B,Hk,g,hd)."""
    B, _, H, hd = q.shape
    Sl, Hk = k.shape[1], k.shape[2]
    g = H // Hk
    qf = q.astype(jnp.float32).reshape(B, Hk, g, hd)
    s = jnp.einsum("bhgd,bshd->bhgs", qf, k.astype(jnp.float32)) / math.sqrt(hd)
    s = jnp.where(valid[:, None, None], s, NEG_INF)
    m = jnp.max(s, axis=-1)  # (B,Hk,g)
    p = jnp.exp(s - m[..., None])
    p = jnp.where(valid[:, None, None], p, 0.0)
    l = jnp.sum(p, axis=-1)
    o = jnp.einsum("bhgs,bshd->bhgd", p, v.astype(jnp.float32))
    return m, l, o


def sharded_decode_attention(q, cache_k, cache_v, k_new, v_new, pos, mesh, *, seq_axis="model"):
    """Write (k_new, v_new) at ``pos`` into the seq-sharded cache and attend.

    q: (B,1,H,hd); cache_k/v: (B,Sc,Hk,hd) sharded P(dp, seq_axis, None, None);
    k_new/v_new: (B,1,Hk,hd); pos: scalar int32 (slot index, ring-resolved by
    the caller).  Returns (out (B,1,H,hd), new cache_k, new cache_v).
    """
    B, _, H, hd = q.shape
    Sc = cache_k.shape[1]
    dp = tuple(a for a in DATA_AXES if a in mesh.axis_names)
    bspec = dp if B % max(
        1, math.prod(mesh.shape[a] for a in dp)
    ) == 0 and B > 1 else None
    cspec = P(bspec, seq_axis if Sc % mesh.shape[seq_axis] == 0 else None, None, None)
    qspec = P(bspec, None, None, None)

    n_shards = mesh.shape[seq_axis] if cspec[1] is not None else 1

    def body(q, ck, cv, kn, vn, pos):
        Sl = ck.shape[1]
        if n_shards > 1:
            ax = lax.axis_index(seq_axis)
        else:
            ax = jnp.int32(0)
        wslot = pos % (Sl * n_shards)  # ring-buffer write slot
        owner = wslot // Sl
        owned = owner == ax
        local_slot = jnp.clip(wslot - ax * Sl, 0, Sl - 1).astype(jnp.int32)
        z = jnp.int32(0)
        # non-owners re-write the slot's current contents: O(1), no resharding
        cur_k = lax.dynamic_slice(ck, (z, local_slot, z, z), kn.shape)
        cur_v = lax.dynamic_slice(cv, (z, local_slot, z, z), vn.shape)
        kw = jnp.where(owned, kn.astype(ck.dtype), cur_k)
        vw = jnp.where(owned, vn.astype(cv.dtype), cur_v)
        ck = lax.dynamic_update_slice(ck, kw, (z, local_slot, z, z))
        cv = lax.dynamic_update_slice(cv, vw, (z, local_slot, z, z))

        spos = ax * Sl + jnp.arange(Sl)  # global positions of local slots
        valid = jnp.broadcast_to((spos <= pos)[None], (ck.shape[0], Sl))
        m, l, o = _local_lse_attend(q, ck, cv, valid)
        if n_shards > 1:
            m_g = lax.pmax(m, seq_axis)
            corr = jnp.exp(m - m_g)
            l_g = lax.psum(l * corr, seq_axis)
            o_g = lax.psum(o * corr[..., None], seq_axis)
        else:
            l_g, o_g = l, o
        out = o_g / jnp.maximum(l_g, 1e-30)[..., None]
        Bl = q.shape[0]
        out = out.reshape(Bl, 1, H, hd).astype(q.dtype)
        return out, ck, cv

    return shard_map(
        body,
        mesh=mesh,
        in_specs=(qspec, cspec, cspec, qspec, qspec, P()),
        out_specs=(qspec, cspec, cspec),
        check_vma=False,
    )(q, cache_k, cache_v, k_new, v_new, pos)
