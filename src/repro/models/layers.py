"""Shared neural layers for the model zoo.

Everything is a pure function over explicit parameter pytrees (dict of
arrays).  No framework (flax/haiku) is used: the params are plain pytrees so
that pjit in_shardings / NamedSharding rules (distributed/sharding.py) can be
zipped against them path-by-path.

Design notes
------------
* Attention is implemented as a CHUNKED online-softmax scan (flash-attention
  schedule in pure jnp).  This is what makes ``prefill_32k`` lowerable: naive
  (S, S) score materialisation at 32k/500k would not fit any memory budget.
  The Pallas kernel in ``repro.kernels.flash_attention`` implements the same
  schedule with explicit VMEM BlockSpecs for TPU; this jnp version is both
  the CPU-lowerable default and the kernel's oracle.
* Sliding-window attention bounds the KV range per query chunk with a
  dynamic slice, so SWA prefill is O(S * W) not O(S^2).
* All matmuls run in the config's activation dtype (bf16 by default) with
  f32 softmax/normalizer accumulation.
"""
from __future__ import annotations

import math
from functools import partial

import jax
import jax.numpy as jnp
from jax import lax

# ----------------------------------------------------------------- norms


def rms_norm(x: jax.Array, scale: jax.Array, eps: float = 1e-5) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    out = x * lax.rsqrt(var + eps)
    return (out * (1.0 + scale.astype(jnp.float32))).astype(dt)


def init_rms_norm(d: int, dtype=jnp.float32) -> jax.Array:
    # stored as (scale - 1) so zero-init == identity
    return jnp.zeros((d,), dtype)


# ----------------------------------------------------------------- RoPE


def rope_angles(positions: jax.Array, head_dim: int, theta: float) -> tuple[jax.Array, jax.Array]:
    """cos/sin tables for the given absolute positions. positions: (...,S)."""
    half = head_dim // 2
    freqs = jnp.exp(-math.log(theta) * jnp.arange(half, dtype=jnp.float32) / half)
    ang = positions[..., None].astype(jnp.float32) * freqs  # (...,S,half)
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x: jax.Array, cos: jax.Array, sin: jax.Array) -> jax.Array:
    """x: (B,S,H,hd); cos/sin: (S,half) or (B,S,half)."""
    dt = x.dtype
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half].astype(jnp.float32), x[..., half:].astype(jnp.float32)
    if cos.ndim == 2:  # (S,half) -> (1,S,1,half)
        cos, sin = cos[None, :, None, :], sin[None, :, None, :]
    elif cos.ndim == 3:  # (B,S,half) -> (B,S,1,half)
        cos, sin = cos[:, :, None, :], sin[:, :, None, :]
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(dt)


# ----------------------------------------------------------------- MLP


def swiglu(x: jax.Array, w_gate: jax.Array, w_up: jax.Array, w_down: jax.Array) -> jax.Array:
    dt = x.dtype
    g = jnp.einsum("...d,df->...f", x, w_gate.astype(dt))
    u = jnp.einsum("...d,df->...f", x, w_up.astype(dt))
    h = jax.nn.silu(g.astype(jnp.float32)).astype(dt) * u
    return jnp.einsum("...f,fd->...d", h, w_down.astype(dt))


# ------------------------------------------------------- chunked attention

NEG_INF = -1e30


def _chunk_attend(q, k, v, qpos0, kpos0, *, causal: bool, window: int):
    """One (q-chunk x kv-chunk) tile. q:(B,Q,H,hd) k/v:(B,Kc,Hk,hd).

    Returns (scores_max, exp_scores @ v, sumexp) in f32 — the online-softmax
    partial terms.  GQA: H % Hk == 0, q heads grouped over kv heads.
    """
    B, Q, H, hd = q.shape
    Hk = k.shape[2]
    group = H // Hk
    qf = q.astype(jnp.float32).reshape(B, Q, Hk, group, hd)
    kf = k.astype(jnp.float32)
    s = jnp.einsum("bqhgd,bkhd->bhgqk", qf, kf) / math.sqrt(hd)
    qpos = qpos0 + jnp.arange(Q)
    kpos = kpos0 + jnp.arange(k.shape[1])
    mask = jnp.ones((Q, k.shape[1]), bool)
    if causal:
        mask &= qpos[:, None] >= kpos[None, :]
    if window > 0:
        mask &= qpos[:, None] - kpos[None, :] < window
    s = jnp.where(mask[None, None, None], s, NEG_INF)
    m = jnp.max(s, axis=-1)  # (B,Hk,g,Q)
    p = jnp.exp(s - m[..., None])
    l = jnp.sum(p, axis=-1)
    o = jnp.einsum("bhgqk,bkhd->bhgqd", p, v.astype(jnp.float32))
    return m, o, l


def chunked_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
    window: int = 0,
    q_chunk: int = 512,
    kv_chunk: int = 512,
    unroll: bool = False,
) -> jax.Array:
    """Flash-style attention in pure jnp.  q/k/v: (B,S,H|Hk,hd) -> (B,S,H,hd).

    Scans over query chunks (outer) and kv chunks (inner) with online
    softmax accumulation.  For sliding windows, each query chunk only scans
    the kv chunks that can fall inside the window (dynamic slice), so cost is
    O(S*W).  For causal full attention the inner scan covers prefix chunks
    only via masking + early bound on the scan length.
    """
    B, S, H, hd = q.shape
    Hk = k.shape[2]
    q_chunk = min(q_chunk, S)
    kv_chunk = min(kv_chunk, S)
    assert S % q_chunk == 0 and S % kv_chunk == 0, (S, q_chunk, kv_chunk)
    nq = S // q_chunk

    if window > 0:
        # kv range needed by q chunk starting at t0: [t0 - window + 1, t0 + q_chunk)
        span = q_chunk + ((window + kv_chunk - 1) // kv_chunk) * kv_chunk
        span = min(span, S)

        def per_qchunk(iq):
            t0 = iq * q_chunk
            qc = lax.dynamic_slice_in_dim(q, t0, q_chunk, axis=1)
            start = jnp.maximum(t0 + q_chunk - span, 0)
            kc = lax.dynamic_slice_in_dim(k, start, span, axis=1)
            vc = lax.dynamic_slice_in_dim(v, start, span, axis=1)
            m, o, l = _chunk_attend(qc, kc, vc, t0, start, causal=causal, window=window)
            out = o / jnp.maximum(l, 1e-30)[..., None]
            return out.reshape(B, Hk * (H // Hk), q_chunk, hd).transpose(0, 2, 1, 3)

        if unroll:  # analysis mode: every tile visible to HloCostAnalysis
            outs = jnp.stack([per_qchunk(jnp.int32(i)) for i in range(nq)])
        else:
            outs = lax.map(per_qchunk, jnp.arange(nq))  # (nq,B,qc,H,hd)
        return outs.transpose(1, 0, 2, 3, 4).reshape(B, S, H, hd).astype(q.dtype)

    nk = S // kv_chunk
    kr = k.reshape(B, nk, kv_chunk, Hk, hd)
    vr = v.reshape(B, nk, kv_chunk, Hk, hd)

    def per_qchunk(iq, static_iq=None):
        t0 = iq * q_chunk
        qc = lax.dynamic_slice_in_dim(q, t0, q_chunk, axis=1)

        def attend_tile(carry, kc, vc, k0):
            m_run, l_run, o_run = carry
            m, o, l = _chunk_attend(qc, kc, vc, t0, k0, causal=causal, window=0)
            m_new = jnp.maximum(m_run, m)
            c1 = jnp.exp(m_run - m_new)
            c2 = jnp.exp(m - m_new)
            return (m_new, l_run * c1 + l * c2, o_run * c1[..., None] + o * c2[..., None])

        def body(carry, ik):
            valid = jnp.logical_or(jnp.logical_not(causal), ik * kv_chunk <= t0 + q_chunk - 1)
            new = lax.cond(
                valid,
                lambda c: attend_tile(c, kr[:, ik], vr[:, ik], ik * kv_chunk),
                lambda c: c,
                carry,
            )
            return new, None

        g = H // Hk
        m0 = jnp.full((B, Hk, g, q_chunk), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, Hk, g, q_chunk), jnp.float32)
        o0 = jnp.zeros((B, Hk, g, q_chunk, hd), jnp.float32)
        carry = (m0, l0, o0)
        if static_iq is not None:  # analysis mode: static causal tile skip
            hi = static_iq + 1 if causal else nk
            for ik in range(hi):
                carry = attend_tile(carry, kr[:, ik], vr[:, ik], ik * kv_chunk)
            m_f, l_f, o_f = carry
        else:
            (m_f, l_f, o_f), _ = lax.scan(body, carry, jnp.arange(nk))
        out = o_f / jnp.maximum(l_f, 1e-30)[..., None]
        return out.reshape(B, H, q_chunk, hd).transpose(0, 2, 1, 3)

    if unroll:
        outs = jnp.stack([per_qchunk(jnp.int32(i), static_iq=i) for i in range(nq)])
    else:
        outs = lax.map(per_qchunk, jnp.arange(nq))
    return outs.transpose(1, 0, 2, 3, 4).reshape(B, S, H, hd).astype(q.dtype)


def decode_attention(q: jax.Array, k_cache: jax.Array, v_cache: jax.Array, valid: jax.Array) -> jax.Array:
    """Single-token attention against a cache.

    q: (B,1,H,hd); caches: (B,Scache,Hk,hd); valid: (B,Scache) bool mask of
    live cache slots (supports both linear and ring-buffer caches).
    Naive einsum is fine here — scores are (B,H,1,S), tiny per device, and
    the cache seq dim may be sharded (GSPMD turns the softmax reductions into
    cheap (B,H) collectives).
    """
    B, _, H, hd = q.shape
    S, Hk = k_cache.shape[1], k_cache.shape[2]
    g = H // Hk
    qf = q.astype(jnp.float32).reshape(B, Hk, g, hd)
    s = jnp.einsum("bhgd,bshd->bhgs", qf, k_cache.astype(jnp.float32)) / math.sqrt(hd)
    s = jnp.where(valid[:, None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgs,bshd->bhgd", p, v_cache.astype(jnp.float32))
    return o.reshape(B, 1, H, hd).astype(q.dtype)
