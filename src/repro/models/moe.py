"""Mixture-of-Experts block (Mixtral / Qwen2-MoE style).

Distribution design (see DESIGN.md §4): the dispatch is the part GSPMD cannot
be trusted to shard well — a global capacity-based scatter would either
replicate the (E, C, D) dispatch tensor or psum it.  So the MoE interior runs
under ``shard_map``: tokens stay LOCAL to their (pod, data) shard, routing /
capacity / scatter are purely local (the paper's mapper-locality argument:
per-shard statistics, no key-value shuffle), and the expert FFN is tensor-
parallel over the ``model`` axis with one psum for the partial down-proj —
the same collective cost as a dense Megatron MLP.

The router's load-balance statistics (per-expert token fractions and mean
probabilities) are ADDITIVE across shards and are combined with ``pmean`` —
the exact key-value-free aggregation pattern of the paper (§4.3.2).

On a single device (CPU smoke tests) the same local function runs without
shard_map.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P
from jax import shard_map

DATA_AXES = ("pod", "data")


def init_moe_params(key, cfg, dtype) -> dict:
    d, f, e = cfg.d_model, cfg.resolved_moe_d_ff, cfg.num_experts
    ks = jax.random.split(key, 5)
    s_in = 1.0 / jnp.sqrt(d)
    s_out = 1.0 / jnp.sqrt(f)
    p = {
        "w_router": (jax.random.normal(ks[0], (d, e)) * s_in).astype(jnp.float32),
        "w_gate": (jax.random.normal(ks[1], (e, d, f)) * s_in).astype(dtype),
        "w_up": (jax.random.normal(ks[2], (e, d, f)) * s_in).astype(dtype),
        "w_down": (jax.random.normal(ks[3], (e, f, d)) * s_out).astype(dtype),
    }
    if cfg.num_shared_experts:
        fs = f * cfg.num_shared_experts
        k1, k2, k3 = jax.random.split(ks[4], 3)
        p["w_shared_gate"] = (jax.random.normal(k1, (d, fs)) * s_in).astype(dtype)
        p["w_shared_up"] = (jax.random.normal(k2, (d, fs)) * s_in).astype(dtype)
        p["w_shared_down"] = (jax.random.normal(k3, (fs, d)) * s_out).astype(dtype)
    return p


def _local_moe(params, x, cfg, *, capacity_factor: float, model_axis: str | None):
    """Local-token MoE. x: (T, D) tokens owned by this shard.

    When ``model_axis`` is set we are inside shard_map: expert weights arrive
    sliced on the hidden (f) dim and the down-proj partial sum is psum'd.
    """
    T, D = x.shape
    E, k = cfg.num_experts, cfg.experts_per_token

    logits = jnp.einsum("td,de->te", x.astype(jnp.float32), params["w_router"])
    probs = jax.nn.softmax(logits, axis=-1)
    top_w, top_e = lax.top_k(probs, k)  # (T,k)
    top_w = top_w / jnp.sum(top_w, axis=-1, keepdims=True)

    # ---- capacity dispatch (local; no cross-shard communication)
    C = max(int(round(k * T / E * capacity_factor)), 1)
    e_flat = top_e.reshape(-1)  # (T*k,)
    w_flat = top_w.reshape(-1)
    oh = jax.nn.one_hot(e_flat, E, dtype=jnp.int32)  # (T*k, E)
    pos = jnp.cumsum(oh, axis=0) - oh  # position within expert
    pos_of = jnp.take_along_axis(pos, e_flat[:, None], axis=1)[:, 0]
    keep = pos_of < C
    dest = e_flat * C + jnp.minimum(pos_of, C - 1)

    x_rep = jnp.repeat(x, k, axis=0)  # (T*k, D)
    x_disp = jnp.zeros((E * C, D), x.dtype).at[dest].add(
        jnp.where(keep[:, None], x_rep, 0), mode="drop"
    )
    x_disp = x_disp.reshape(E, C, D)

    # ---- expert FFN (hidden dim possibly sliced over the model axis)
    wg, wu, wd = params["w_gate"], params["w_up"], params["w_down"]
    g = jnp.einsum("ecd,edf->ecf", x_disp, wg.astype(x.dtype))
    u = jnp.einsum("ecd,edf->ecf", x_disp, wu.astype(x.dtype))
    h = jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype) * u
    y_disp = jnp.einsum("ecf,efd->ecd", h, wd.astype(x.dtype))
    if model_axis is not None:
        y_disp = lax.psum(y_disp, model_axis)

    # ---- combine back to tokens
    y_flat = y_disp.reshape(E * C, D)[dest]
    y_flat = y_flat * (keep[:, None] * w_flat[:, None]).astype(x.dtype)
    y = y_flat.reshape(T, k, D).sum(axis=1)

    # ---- shared experts (dense; hidden also sliced over model axis)
    if cfg.num_shared_experts:
        sg = jnp.einsum("td,df->tf", x, params["w_shared_gate"].astype(x.dtype))
        su = jnp.einsum("td,df->tf", x, params["w_shared_up"].astype(x.dtype))
        sh = jax.nn.silu(sg.astype(jnp.float32)).astype(x.dtype) * su
        ys = jnp.einsum("tf,fd->td", sh, params["w_shared_down"].astype(x.dtype))
        if model_axis is not None:
            ys = lax.psum(ys, model_axis)
        y = y + ys

    # ---- load-balance stats (additive across shards, psum'd by the caller)
    frac = jnp.mean(jax.nn.one_hot(top_e, E, dtype=jnp.float32), axis=(0, 1))  # (E,)
    pmean = jnp.mean(probs, axis=0)
    aux = E * jnp.sum(frac * pmean)
    return y, aux


def _local_moe_decode(params, x, cfg, *, model_axis: str | None):
    """Gather-based MoE for tiny token counts (decode): instead of capacity
    dispatch (which would drop tokens at T ~ batch), gather each token's k
    expert weight slices and compute them directly.  O(T * k) expert matmuls
    — negligible next to attention at decode time."""
    T, D = x.shape
    E, k = cfg.num_experts, cfg.experts_per_token
    logits = jnp.einsum("td,de->te", x.astype(jnp.float32), params["w_router"])
    probs = jax.nn.softmax(logits, axis=-1)
    top_w, top_e = lax.top_k(probs, k)
    top_w = top_w / jnp.sum(top_w, axis=-1, keepdims=True)

    wg = params["w_gate"][top_e]  # (T,k,D,F)
    wu = params["w_up"][top_e]
    wd = params["w_down"][top_e]  # (T,k,F,D)
    g = jnp.einsum("td,tkdf->tkf", x, wg.astype(x.dtype))
    u = jnp.einsum("td,tkdf->tkf", x, wu.astype(x.dtype))
    h = jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype) * u
    y = jnp.einsum("tkf,tkfd,tk->td", h, wd.astype(x.dtype), top_w.astype(x.dtype))
    if model_axis is not None:
        y = lax.psum(y, model_axis)

    if cfg.num_shared_experts:
        sg = jnp.einsum("td,df->tf", x, params["w_shared_gate"].astype(x.dtype))
        su = jnp.einsum("td,df->tf", x, params["w_shared_up"].astype(x.dtype))
        sh = jax.nn.silu(sg.astype(jnp.float32)).astype(x.dtype) * su
        ys = jnp.einsum("tf,fd->td", sh, params["w_shared_down"].astype(x.dtype))
        if model_axis is not None:
            ys = lax.psum(ys, model_axis)
        y = y + ys

    frac = jnp.mean(jax.nn.one_hot(top_e, E, dtype=jnp.float32), axis=(0, 1))
    aux = E * jnp.sum(frac * jnp.mean(probs, axis=0))
    return y, aux


DECODE_GATHER_MAX_TOKENS = 256


def moe_block(params, x, cfg, *, mesh=None, capacity_factor: float = 1.25):
    """x: (B, S, D) -> (y, aux_loss).  mesh=None => single-device path."""
    B, S, D = x.shape
    xt = x.reshape(B * S, D)
    if mesh is None:
        if B * S <= DECODE_GATHER_MAX_TOKENS:
            y, aux = _local_moe_decode(params, xt, cfg, model_axis=None)
        else:
            y, aux = _local_moe(params, xt, cfg, capacity_factor=capacity_factor, model_axis=None)
        return y.reshape(B, S, D), aux

    has_shared = cfg.num_shared_experts > 0
    dp = tuple(a for a in DATA_AXES if a in mesh.axis_names)
    dp_size = 1
    for a in dp:
        dp_size *= mesh.shape[a]
    if (B * S) % dp_size:  # e.g. long_500k decode with batch 1: tokens can't
        dp, dp_size = (), 1  # shard over data — replicate, TP-only interior
    local_tokens = (B * S) // dp_size
    tok_spec = P(dp if dp else None, None)

    pspec = {
        "w_router": P(None, None),
        "w_gate": P(None, "data", "model"),
        "w_up": P(None, "data", "model"),
        "w_down": P(None, "model", "data"),
    }
    if has_shared:
        pspec["w_shared_gate"] = P("data", "model")
        pspec["w_shared_up"] = P("data", "model")
        pspec["w_shared_down"] = P("model", "data")

    gather_dtype = cfg.activation_dtype if cfg.bf16_weight_gather else None

    def body(params, xt):
        # manual FSDP: un-shard the weight's d_model dim over the data axis
        # (bf16_weight_gather lever: cast the shard BEFORE gathering)
        cast = (lambda w: w.astype(gather_dtype)) if gather_dtype else (lambda w: w)
        p = dict(params)
        p["w_gate"] = lax.all_gather(cast(params["w_gate"]), "data", axis=1, tiled=True)
        p["w_up"] = lax.all_gather(cast(params["w_up"]), "data", axis=1, tiled=True)
        p["w_down"] = lax.all_gather(cast(params["w_down"]), "data", axis=2, tiled=True)
        if has_shared:
            p["w_shared_gate"] = lax.all_gather(cast(params["w_shared_gate"]), "data", axis=0, tiled=True)
            p["w_shared_up"] = lax.all_gather(cast(params["w_shared_up"]), "data", axis=0, tiled=True)
            p["w_shared_down"] = lax.all_gather(cast(params["w_shared_down"]), "data", axis=1, tiled=True)
        if local_tokens <= DECODE_GATHER_MAX_TOKENS:
            y, aux = _local_moe_decode(p, xt, cfg, model_axis="model")
        else:
            y, aux = _local_moe(p, xt, cfg, capacity_factor=capacity_factor, model_axis="model")
        aux = lax.pmean(aux, "model")
        if dp:
            aux = lax.pmean(aux, dp)
        return y, aux

    y, aux = shard_map(
        body,
        mesh=mesh,
        in_specs=(pspec, tok_spec),
        out_specs=(tok_spec, P()),
        check_vma=False,
    )(params, xt)
    return y.reshape(B, S, D), aux


def moe_param_specs(cfg) -> dict:
    """PartitionSpecs matching the shard_map in_specs above (used by the
    global sharding rules so pjit in_shardings agree with the interior)."""
    spec = {
        "w_router": P(None, None),
        "w_gate": P(None, "data", "model"),
        "w_up": P(None, "data", "model"),
        "w_down": P(None, "model", "data"),
    }
    if cfg.num_shared_experts:
        spec["w_shared_gate"] = P("data", "model")
        spec["w_shared_up"] = P("data", "model")
        spec["w_shared_down"] = P("model", "data")
    return spec
