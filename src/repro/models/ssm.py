"""Mamba2 / SSD (state-space duality) blocks [arXiv:2405.21060].

Training/prefill uses the chunked SSD algorithm: the sequence is split into
chunks of length L; within a chunk the recurrence is expanded as a masked
(lower-triangular, decay-weighted) matmul (MXU-friendly), and across chunks a
short ``lax.scan`` carries the (H, P, N) state.  This is the TPU-native
adaptation of the paper's separability argument: chunk states are ADDITIVE
sufficient statistics, exactly like the DFNTF mapper stats, so the sequential
part is only S/L steps long.

Decode uses the O(1) recurrent update: h <- exp(dt*A) h + dt * B ouFter x.

Shapes follow the Mamba2 conventions with n_groups=1:
  x (values):   (B, S, H, P)      P = ssm_head_dim
  B, C:         (B, S, N)         N = ssm_state
  dt:           (B, S, H)         softplus-positive step size
  A:            (H,)              negative decay rate (stored as log)
  D:            (H,)              skip
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from repro.models.layers import rms_norm


def _segsum(a: jax.Array) -> jax.Array:
    """Stable segment-sum: out[..., i, j] = sum_{k=j+1..i} a[..., k] for i>=j,
    -inf otherwise.  a: (..., L) -> (..., L, L)."""
    L = a.shape[-1]
    cs = jnp.cumsum(a, axis=-1)
    diff = cs[..., :, None] - cs[..., None, :]  # sum over (j, i]
    mask = jnp.tril(jnp.ones((L, L), bool), k=0)
    return jnp.where(mask, diff, -jnp.inf)


def ssd_chunked(x, dt, A_log, Bm, Cm, D, *, chunk: int, initial_state=None, unroll: bool = False):
    """Chunked SSD scan.

    x: (B,S,H,P), dt: (B,S,H), A_log: (H,), Bm/Cm: (B,S,N), D: (H,)
    Returns y: (B,S,H,P), final_state: (B,H,P,N).
    """
    Bsz, S, H, P = x.shape
    N = Bm.shape[-1]
    chunk = min(chunk, S)
    assert S % chunk == 0, (S, chunk)
    nc = S // chunk

    f32 = jnp.float32
    a = (dt.astype(f32) * (-jnp.exp(A_log.astype(f32)))[None, None])  # (B,S,H) negative
    xdt = x.astype(f32) * dt.astype(f32)[..., None]  # (B,S,H,P) dt-weighted values

    # reshape into chunks
    ar = a.reshape(Bsz, nc, chunk, H)
    xr = xdt.reshape(Bsz, nc, chunk, H, P)
    Br = Bm.astype(f32).reshape(Bsz, nc, chunk, N)
    Cr = Cm.astype(f32).reshape(Bsz, nc, chunk, N)

    # ---- intra-chunk (dual / quadratic form): Y_intra = (C B^T * decay) Xdt
    seg = _segsum(ar.transpose(0, 1, 3, 2))  # (B,nc,H,L,L)
    decay = jnp.exp(seg)
    scores = jnp.einsum("bcln,bcmn->bclm", Cr, Br)  # (B,nc,L,L)
    y_intra = jnp.einsum("bclm,bchlm,bcmhp->bclhp", scores, decay, xr)

    # ---- chunk states: additive sufficient stats per chunk
    cum = jnp.cumsum(ar, axis=2)  # (B,nc,L,H)
    tail = cum[:, :, -1:, :] - cum  # decay from position l to end of chunk
    w = jnp.exp(tail)  # (B,nc,L,H)
    states = jnp.einsum("bcln,bclh,bclhp->bchpn", Br, w, xr)  # (B,nc,H,P,N)

    # ---- inter-chunk recurrence over nc chunks (short sequential scan)
    chunk_decay = jnp.exp(cum[:, :, -1, :])  # (B,nc,H) total decay of chunk

    if initial_state is None:
        h0 = jnp.zeros((Bsz, H, P, N), f32)
    else:
        h0 = initial_state.astype(f32)

    def step(h, inp):
        st, dk = inp  # (B,H,P,N), (B,H)
        h_out = h  # state BEFORE this chunk
        h_new = h * dk[..., None, None] + st
        return h_new, h_out

    hT, h_prev = lax.scan(
        step,
        h0,
        (states.transpose(1, 0, 2, 3, 4), chunk_decay.transpose(1, 0, 2)),
        unroll=True if unroll else 1,
    )
    h_prev = h_prev.transpose(1, 0, 2, 3, 4)  # (B,nc,H,P,N) state entering each chunk

    # ---- contribution of carried state into each chunk
    into = jnp.exp(cum)  # decay from chunk start to position l (inclusive)
    y_inter = jnp.einsum("bcln,bclh,bchpn->bclhp", Cr, into, h_prev)

    skip = x.astype(f32).reshape(Bsz, nc, chunk, H, P) * D.astype(f32)[None, None, None, :, None]
    y = y_intra + y_inter + skip
    return y.reshape(Bsz, S, H, P).astype(x.dtype), hT.astype(x.dtype)


def ssd_decode_step(h, x, dt, A_log, Bm, Cm, D):
    """One-token recurrent update.

    h: (B,H,P,N) carried state; x: (B,H,P); dt: (B,H); Bm/Cm: (B,N).
    Returns y: (B,H,P), h_new.
    """
    f32 = jnp.float32
    a = jnp.exp(dt.astype(f32) * (-jnp.exp(A_log.astype(f32)))[None])  # (B,H)
    xdt = x.astype(f32) * dt.astype(f32)[..., None]  # (B,H,P)
    h_new = h.astype(f32) * a[..., None, None] + jnp.einsum("bhp,bn->bhpn", xdt, Bm.astype(f32))
    y = jnp.einsum("bhpn,bn->bhp", h_new, Cm.astype(f32)) + x.astype(f32) * D.astype(f32)[None, :, None]
    return y.astype(x.dtype), h_new.astype(x.dtype)


# --------------------------------------------------------------- full block


def _causal_conv(x: jax.Array, w: jax.Array) -> jax.Array:
    """Depthwise causal conv. x: (B,S,C), w: (K,C)."""
    K = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (K - 1, 0), (0, 0)))
    out = jnp.zeros_like(x, dtype=jnp.float32)
    for k in range(K):
        out = out + xp[:, k : k + x.shape[1]].astype(jnp.float32) * w[k].astype(jnp.float32)
    return out.astype(x.dtype)


def init_mamba2_params(key, cfg, dtype) -> dict:
    d, di, N = cfg.d_model, cfg.d_inner, cfg.ssm_state
    H = cfg.ssm_heads
    conv_dim = di + 2 * N
    k1, k2, k3, k4 = jax.random.split(key, 4)
    s_in = 1.0 / jnp.sqrt(d)
    return {
        # in_proj -> [z (gate), x, B, C, dt]
        "w_in": (jax.random.normal(k1, (d, 2 * di + 2 * N + H)) * s_in).astype(dtype),
        "conv_w": (jax.random.normal(k2, (cfg.ssm_conv, conv_dim)) * 0.1).astype(dtype),
        "A_log": jnp.zeros((H,), jnp.float32),  # A = -exp(0) = -1
        "D": jnp.ones((H,), jnp.float32),
        "dt_bias": jnp.zeros((H,), jnp.float32),
        "norm_scale": jnp.zeros((di,), jnp.float32),
        "w_out": (jax.random.normal(k3, (di, d)) * (1.0 / jnp.sqrt(di))).astype(dtype),
    }


def mamba2_block(params, x, cfg, *, constrain=lambda t, kind: t, return_cache=False):
    """Full Mamba2 mixer over a sequence.  x: (B,S,d_model).

    With ``return_cache`` also returns {state, conv} in the decode-cache
    layout (final SSD state + last conv-window inputs)."""
    B, S, d = x.shape
    di, N, H, P = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_head_dim
    w_in = params["w_in"].astype(x.dtype)
    if cfg.bf16_weight_gather:
        w_in = constrain(w_in, "w_col")
    proj = jnp.einsum("bsd,de->bse", x, w_in)
    z, xv, Bm, Cm, dt = jnp.split(proj, [di, 2 * di, 2 * di + N, 2 * di + 2 * N], axis=-1)
    conv_in = jnp.concatenate([xv, Bm, Cm], axis=-1)
    conv_out = jax.nn.silu(_causal_conv(conv_in, params["conv_w"]).astype(jnp.float32)).astype(x.dtype)
    xv, Bm, Cm = jnp.split(conv_out, [di, di + N], axis=-1)
    xv = constrain(xv.reshape(B, S, H, P), "ssm_x")
    dt = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"][None, None])
    y, hT = ssd_chunked(
        xv, dt.astype(x.dtype), params["A_log"], Bm, Cm, params["D"],
        chunk=cfg.ssm_chunk, unroll=cfg.inner_unroll,
    )
    y = y.reshape(B, S, di)
    y = rms_norm(y * jax.nn.silu(z.astype(jnp.float32)).astype(x.dtype), params["norm_scale"], cfg.norm_eps)
    w_out = params["w_out"].astype(x.dtype)
    if cfg.bf16_weight_gather:
        w_out = constrain(w_out, "w_row")
    out = jnp.einsum("bse,ed->bsd", y, w_out)
    if return_cache:
        K = cfg.ssm_conv
        cache = {"state": hT, "conv": conv_in[:, S - (K - 1) :]}
        return out, cache
    return out


def init_mamba2_cache(cfg, batch, dtype) -> dict:
    H, P, N = cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state
    conv_dim = cfg.d_inner + 2 * N
    return {
        "state": jnp.zeros((batch, H, P, N), dtype),
        "conv": jnp.zeros((batch, cfg.ssm_conv - 1, conv_dim), dtype),
    }


def mamba2_decode(params, x, cache, cfg):
    """One-token step. x: (B,d_model), cache: {state, conv} -> (y, cache)."""
    B, d = x.shape
    di, N, H, P = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_head_dim
    proj = jnp.einsum("bd,de->be", x, params["w_in"].astype(x.dtype))
    z, xv, Bm, Cm, dt = jnp.split(proj, [di, 2 * di, 2 * di + N, 2 * di + 2 * N], axis=-1)
    conv_in = jnp.concatenate([xv, Bm, Cm], axis=-1)  # (B, conv_dim)
    window = jnp.concatenate([cache["conv"], conv_in[:, None]], axis=1)  # (B,K,conv)
    w = params["conv_w"]
    conv_out = jnp.einsum("bkc,kc->bc", window.astype(jnp.float32), w.astype(jnp.float32))
    conv_out = jax.nn.silu(conv_out).astype(x.dtype)
    xv, Bm, Cm = jnp.split(conv_out, [di, di + N], axis=-1)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"][None])
    y, h_new = ssd_decode_step(
        cache["state"], xv.reshape(B, H, P), dt.astype(x.dtype), params["A_log"], Bm, Cm, params["D"]
    )
    y = y.reshape(B, di)
    y = rms_norm(y * jax.nn.silu(z.astype(jnp.float32)).astype(x.dtype), params["norm_scale"], cfg.norm_eps)
    out = jnp.einsum("be,ed->bd", y, params["w_out"].astype(x.dtype))
    new_cache = {"state": h_new, "conv": window[:, 1:]}
    return out, new_cache
