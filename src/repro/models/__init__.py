from repro.models.lm import (
    init_decode_cache,
    init_lm_params,
    lm_decode_step,
    lm_forward,
    lm_loss,
)
