"""Decoder LM zoo: dense / MoE / SSM / hybrid / audio / VLM families.

One parameter pytree + three pure entry points per architecture:

  * ``init_lm_params(key, cfg)``      — parameter pytree (f32 master copies;
    forward casts to ``cfg.activation_dtype`` at use).
  * ``lm_forward(params, batch, cfg)``— full-sequence logits (train/prefill).
  * ``lm_decode_step(params, cache, tokens, pos, cfg)`` — one-token decode
    against a KV/SSM cache (``serve_step``).

Layers are stacked on a leading L axis and applied with ``lax.scan`` (keeps
the HLO O(1) in depth) with ``jax.checkpoint`` on the body (remat).

Families:
  dense  — pre-norm GQA attention + SwiGLU (granite/deepseek/qwen3/qwen2).
  moe    — attention + MoE FFN (mixtral, qwen2-moe w/ shared experts).
  ssm    — Mamba2/SSD mixer only (mamba2-1.3b).
  hybrid — Mamba2 backbone + ONE SHARED attention+MLP block applied every
           k-th layer (zamba2).
  audio  — dense decoder over EnCodec tokens (musicgen); the conv codec
           frontend is a stub per the assignment carve-out.
  vlm    — dense decoder consuming projected patch embeddings + text tokens
           (llava-next); the ViT tower is a stub, the projector is real.

Sharding: the model takes a ``constrain(x, kind)`` callback (see
``repro.distributed.sharding``).  With ``mesh=None`` (CPU smoke tests)
everything runs unconstrained on one device.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax import lax
from jax.ad_checkpoint import checkpoint_name as _ckpt_name

from repro.models import ssm as ssm_mod
from repro.models.layers import (
    apply_rope,
    chunked_attention,
    decode_attention,
    init_rms_norm,
    rms_norm,
    rope_angles,
    swiglu,
)
from repro.models.moe import init_moe_params, moe_block

D_VISION = 1024  # CLIP ViT-L/14 patch embedding width (llava-next stub)


from functools import partial as _partial


@_partial(jax.custom_vjp, nondiff_argnums=(1,))
def _grad_cast_boundary(x, dtype_name: str):
    return x


def _gcb_fwd(x, dtype_name):
    return x, None


def _gcb_bwd(dtype_name, _res, g):
    # identity forward; backward casts the cotangent to the primal dtype —
    # stops the f32 CE cotangent from riding through every layer's dx psum
    return (g.astype(jnp.dtype(dtype_name)),)


_grad_cast_boundary.defvjp(_gcb_fwd, _gcb_bwd)

Constrain = Callable[[jax.Array, str], jax.Array]
_IDENT: Constrain = lambda x, kind: x


def _wc(w, kind, cfg, constrain, dt):
    """Weight at its use site, cast to the activation dtype.  With the
    bf16_weight_gather lever the cast is pinned (optimization_barrier) and
    the gathered (FSDP-unsharded) form is constrained on the bf16 COPY, so
    the all-gather moves bf16 — XLA otherwise commutes the convert past the
    collective and gathers the f32 master (observed in the probe HLO;
    EXPERIMENTS.md §Perf)."""
    w = w.astype(dt)
    if cfg.bf16_weight_gather:
        w = lax.optimization_barrier(w)
        w = constrain(w, kind)
    return w


def _pin_reduce(delta, cfg):
    """bf16_reduce lever: pin the layer-output partial sum in bf16 so the TP
    all-reduce is not promoted to f32 (XLA moves the consumer's f32 upcast
    before the all-reduce otherwise — 2x the dominant collective)."""
    if cfg.bf16_weight_gather:
        return lax.optimization_barrier(delta)
    return delta


# ================================================================== init


def _init_attention(key, cfg, dtype) -> dict:
    d, H, Hk, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
    ks = jax.random.split(key, 4)
    s = 1.0 / jnp.sqrt(d)
    p = {
        "wq": (jax.random.normal(ks[0], (d, H * hd)) * s).astype(dtype),
        "wk": (jax.random.normal(ks[1], (d, Hk * hd)) * s).astype(dtype),
        "wv": (jax.random.normal(ks[2], (d, Hk * hd)) * s).astype(dtype),
        "wo": (jax.random.normal(ks[3], (H * hd, d)) * (1.0 / jnp.sqrt(H * hd))).astype(dtype),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((H * hd,), dtype)
        p["bk"] = jnp.zeros((Hk * hd,), dtype)
        p["bv"] = jnp.zeros((Hk * hd,), dtype)
    if cfg.qk_norm:
        p["q_norm"] = init_rms_norm(hd, dtype)
        p["k_norm"] = init_rms_norm(hd, dtype)
    return p


def _init_mlp(key, cfg, dtype, d_ff=None) -> dict:
    d, f = cfg.d_model, d_ff or cfg.d_ff
    k1, k2, k3 = jax.random.split(key, 3)
    s_in, s_out = 1.0 / jnp.sqrt(d), 1.0 / jnp.sqrt(f)
    return {
        "w_gate": (jax.random.normal(k1, (d, f)) * s_in).astype(dtype),
        "w_up": (jax.random.normal(k2, (d, f)) * s_in).astype(dtype),
        "w_down": (jax.random.normal(k3, (f, d)) * s_out).astype(dtype),
    }


def _init_layer(key, cfg, dtype) -> dict:
    d = cfg.d_model
    fam = cfg.family
    if fam in ("ssm", "hybrid"):
        k1, _ = jax.random.split(key)
        return {"ln": init_rms_norm(d, dtype), "mamba": ssm_mod.init_mamba2_params(k1, cfg, dtype)}
    k1, k2 = jax.random.split(key)
    layer = {
        "ln1": init_rms_norm(d, dtype),
        "ln2": init_rms_norm(d, dtype),
        "attn": _init_attention(k1, cfg, dtype),
    }
    if fam == "moe":
        layer["moe"] = init_moe_params(k2, cfg, dtype)
    else:
        layer["mlp"] = _init_mlp(k2, cfg, dtype)
    return layer


def init_lm_params(key, cfg, dtype=jnp.float32) -> dict:
    keys = jax.random.split(key, cfg.num_layers + 3)
    layers = [_init_layer(keys[i], cfg, dtype) for i in range(cfg.num_layers)]
    stacked = jax.tree.map(lambda *xs: jnp.stack(xs), *layers)
    params = {
        "embed": (jax.random.normal(keys[-1], (cfg.vocab_size, cfg.d_model)) * 0.02).astype(dtype),
        "layers": stacked,
        "final_norm": init_rms_norm(cfg.d_model, dtype),
    }
    if cfg.family == "hybrid":
        k1, k2 = jax.random.split(keys[-2])
        params["shared_block"] = {
            "ln1": init_rms_norm(cfg.d_model, dtype),
            "ln2": init_rms_norm(cfg.d_model, dtype),
            "attn": _init_attention(k1, cfg, dtype),
            "mlp": _init_mlp(k2, cfg, dtype),
        }
    if cfg.modality == "vision":
        params["vision_proj"] = (
            jax.random.normal(keys[-3], (D_VISION, cfg.d_model)) * (1.0 / jnp.sqrt(D_VISION))
        ).astype(dtype)
    return params


# ============================================================== attention


def _project_qkv(p, x, cfg, constrain):
    B, S, _ = x.shape
    H, Hk, hd = cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
    dt = x.dtype
    q = jnp.einsum("bsd,de->bse", x, _wc(p["wq"], "w_col", cfg, constrain, dt))
    k = jnp.einsum("bsd,de->bse", x, _wc(p["wk"], "w_col", cfg, constrain, dt))
    v = jnp.einsum("bsd,de->bse", x, _wc(p["wv"], "w_col", cfg, constrain, dt))
    if cfg.qkv_bias:
        q, k, v = q + p["bq"].astype(dt), k + p["bk"].astype(dt), v + p["bv"].astype(dt)
    q = constrain(q, "q_proj").reshape(B, S, H, hd)
    k = constrain(k, "kv_proj").reshape(B, S, Hk, hd)
    v = constrain(v, "kv_proj").reshape(B, S, Hk, hd)
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"], cfg.norm_eps)
        k = rms_norm(k, p["k_norm"], cfg.norm_eps)
    return q, k, v


def attention_forward(p, x, cfg, positions, constrain=_IDENT, *, window=None, return_kv=False):
    """Full-sequence attention. x: (B,S,D); positions: (S,) absolute.

    With ``return_kv`` also returns the post-RoPE (k, v) — the prefill path
    trims/rolls them into the decode cache layout."""
    B, S, _ = x.shape
    q, k, v = _project_qkv(p, x, cfg, constrain)
    cos, sin = rope_angles(positions, cfg.resolved_head_dim, cfg.rope_theta)
    q, k = apply_rope(q, cos, sin), apply_rope(k, cos, sin)
    w = cfg.sliding_window if window is None else window
    qc = min(512, S)
    out = chunked_attention(
        q, k, v, causal=True, window=w, q_chunk=qc, kv_chunk=qc, unroll=cfg.inner_unroll
    )
    out = out.reshape(B, S, -1)
    y = jnp.einsum("bse,ed->bsd", out, _wc(p["wo"], "w_row", cfg, constrain, x.dtype))
    if return_kv:
        return y, (k, v)
    return y


def _kv_to_cache(k, v, window: int):
    """Trim full-sequence (B,S,Hk,hd) k/v to the decode cache layout.

    With a sliding window the cache is a ring buffer of the last W
    positions, where position p lives at slot p % W — jnp.roll by S
    reproduces exactly the state token-by-token decoding would have built."""
    S = k.shape[1]
    if window and window < S:
        k = jnp.roll(k[:, S - window :], shift=S % window, axis=1)
        v = jnp.roll(v[:, S - window :], shift=S % window, axis=1)
    return {"k": k, "v": v}


def attention_decode(p, x, cache, pos, cfg, constrain=_IDENT, mesh=None):
    """One-token attention. x: (B,1,D); cache: {k,v:(B,Sc,Hk,hd)};
    pos: scalar int (number of tokens already in the cache).

    If the cache length is smaller than the logical context (sliding-window
    ring buffer), the write goes to slot ``pos % Sc`` and all filled slots
    are valid (the ring holds exactly the last Sc positions).

    With a mesh, the cache is seq-sharded over the ``model`` axis and this
    dispatches to the flash-decoding shard_map path (owner-shard O(1) write +
    log-sum-exp combine) — see ``repro.models.decode_attn``."""
    B = x.shape[0]
    Sc = cache["k"].shape[1]
    q, k, v = _project_qkv(p, x, cfg, constrain)
    cos, sin = rope_angles(pos[None], cfg.resolved_head_dim, cfg.rope_theta)
    q, k = apply_rope(q, cos, sin), apply_rope(k, cos, sin)
    if mesh is not None:
        from repro.models.decode_attn import sharded_decode_attention

        out, k_cache, v_cache = sharded_decode_attention(
            q, cache["k"], cache["v"], k, v, pos.astype(jnp.int32), mesh
        )
    else:
        slot = (pos % Sc).astype(jnp.int32)
        z = jnp.int32(0)
        k_cache = lax.dynamic_update_slice(cache["k"], k.astype(cache["k"].dtype), (z, slot, z, z))
        v_cache = lax.dynamic_update_slice(cache["v"], v.astype(cache["v"].dtype), (z, slot, z, z))
        valid = jnp.arange(Sc)[None, :] <= pos  # (1,Sc) -> broadcast over batch
        valid = jnp.broadcast_to(valid, (B, Sc))
        out = decode_attention(q, k_cache, v_cache, valid)
    out = out.reshape(B, 1, -1)
    y = jnp.einsum("bse,ed->bsd", out, _wc(p["wo"], "w_row", cfg, constrain, x.dtype))
    return y, {"k": k_cache, "v": v_cache}


def _shared_block_forward(p, x, cfg, positions, constrain):
    """Zamba2 shared transformer block (train path): attn + MLP residuals."""
    h = x + attention_forward(p["attn"], rms_norm(x, p["ln1"], cfg.norm_eps), cfg, positions, constrain)
    hin = rms_norm(h, p["ln2"], cfg.norm_eps)
    h = h + constrain(
        swiglu(
            hin,
            _wc(p["mlp"]["w_gate"], "w_col", cfg, constrain, hin.dtype),
            _wc(p["mlp"]["w_up"], "w_col", cfg, constrain, hin.dtype),
            _wc(p["mlp"]["w_down"], "w_row", cfg, constrain, hin.dtype),
        ),
        "act",
    )
    return h


# ===================================================== layer-stack driver


def _layer_scan(body, carry, xs, L: int, unroll: bool, remat: bool = False, cfg=None):
    """Apply ``body(carry, (xs_i, i))`` over L stacked layers.

    Production mode is ``lax.scan`` (HLO O(1) in depth), optionally with
    ``jax.checkpoint`` on the body (train remat).  Analysis mode
    (cfg.scan_unroll) is a Python loop where ``i`` stays a PYTHON int — the
    hybrid shared-block cadence is static (no lax.cond), so HloCostAnalysis
    counts exactly the executed work (it otherwise charges untaken
    conditional branches).  The remat wrapper closes over ``i`` so the
    static index never becomes a traced checkpoint operand.
    """
    policy = None
    if remat and cfg is not None and cfg.remat_save_outputs:
        policy = jax.checkpoint_policies.save_only_these_names("sublayer_out")
    if unroll:
        ys = []
        for i in range(L):
            xi = jax.tree.map(lambda a: a[i], xs)
            fn = (lambda c, x, i=i: body(c, (x, i)))
            if remat:
                fn = jax.checkpoint(fn, policy=policy)
            carry, y = fn(carry, xi)
            ys.append(y)
        if ys and ys[0] is not None:
            ys = jax.tree.map(lambda *zs: jnp.stack(zs), *ys)
        else:
            ys = None
        return carry, ys
    fn = jax.checkpoint(body, policy=policy) if remat else body
    return lax.scan(lambda c, inp: fn(c, inp), carry, (xs, jnp.arange(L)))


def _static_cond(pred, true_fn, false_fn, operand):
    """lax.cond that collapses to a Python branch for static predicates."""
    if isinstance(pred, (bool, int)):
        return true_fn(operand) if pred else false_fn(operand)
    return lax.cond(pred, true_fn, false_fn, operand)


# ================================================================ forward


def _embed_inputs(params, batch, cfg, constrain):
    """Token (+ modality stub) embedding. Returns (B, S, D) activations."""
    dt = cfg.activation_dtype
    x = jnp.take(params["embed"], batch["tokens"], axis=0).astype(dt)
    if cfg.modality == "vision" and "patch_embeds" in batch:
        patches = jnp.einsum(
            "bfe,ed->bfd", batch["patch_embeds"].astype(dt), params["vision_proj"].astype(dt)
        )
        x = jnp.concatenate([patches, x], axis=1)
    return constrain(x, "act")


def lm_forward(params, batch, cfg, *, mesh=None, constrain: Constrain = _IDENT, window=None):
    """Train/prefill forward. batch: {tokens (B,S') [, patch_embeds]}.

    Returns (logits (B,S,V) f32, aux_loss scalar)."""
    x = _embed_inputs(params, batch, cfg, constrain)
    B, S, D = x.shape
    positions = jnp.arange(S)
    fam = cfg.family

    if fam in ("ssm", "hybrid"):
        shared = params.get("shared_block")

        def body(carry, inp):
            x, aux = carry
            lp, i = inp
            if fam == "hybrid":
                x = _static_cond(
                    i % cfg.shared_attn_every == 0,
                    lambda x: _shared_block_forward(shared, x, cfg, positions, constrain),
                    lambda x: x,
                    x,
                )
            h = ssm_mod.mamba2_block(lp["mamba"], rms_norm(x, lp["ln"], cfg.norm_eps), cfg, constrain=constrain)
            return (constrain(x + h, "act"), aux), None

        (x, aux), _ = _layer_scan(
            body, (x, jnp.float32(0.0)), params["layers"],
            cfg.num_layers, cfg.scan_unroll, remat=True, cfg=cfg,
        )
    else:

        def body(carry, inp):
            x, aux = carry
            lp, _ = inp
            attn_out = _pin_reduce(
                attention_forward(
                    lp["attn"], rms_norm(x, lp["ln1"], cfg.norm_eps), cfg, positions, constrain, window=window
                ),
                cfg,
            )
            if cfg.remat_save_outputs:
                attn_out = _ckpt_name(attn_out, "sublayer_out")
            h = x + attn_out
            h = constrain(h, "act")
            hin = rms_norm(h, lp["ln2"], cfg.norm_eps)
            if fam == "moe":
                delta, a = moe_block(lp["moe"], hin, cfg, mesh=mesh)
                aux = aux + a
            else:
                delta = swiglu(
                    hin,
                    _wc(lp["mlp"]["w_gate"], "w_col", cfg, constrain, hin.dtype),
                    _wc(lp["mlp"]["w_up"], "w_col", cfg, constrain, hin.dtype),
                    _wc(lp["mlp"]["w_down"], "w_row", cfg, constrain, hin.dtype),
                )
            delta = _pin_reduce(delta, cfg)
            if cfg.remat_save_outputs:
                delta = _ckpt_name(delta, "sublayer_out")
            return (constrain(h + delta, "act"), aux), None

        (x, aux), _ = _layer_scan(
            body, (x, jnp.float32(0.0)), params["layers"],
            cfg.num_layers, cfg.scan_unroll, remat=True, cfg=cfg,
        )

    if cfg.bf16_cotangents:
        x = _grad_cast_boundary(x, cfg.dtype)
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = jnp.einsum(
        "bsd,vd->bsv",
        x,
        _wc(params["embed"], "w_embed", cfg, constrain, x.dtype),
        preferred_element_type=jnp.float32,
    )
    logits = constrain(logits, "logits")
    return logits, aux / cfg.num_layers


def lm_loss(params, batch, cfg, *, mesh=None, constrain: Constrain = _IDENT, aux_weight=0.01, window=None):
    """Next-token cross-entropy (+ MoE load-balance aux)."""
    logits, aux = lm_forward(params, batch, cfg, mesh=mesh, constrain=constrain, window=window)
    labels, mask = batch["labels"], batch["mask"].astype(jnp.float32)
    logz = jax.scipy.special.logsumexp(logits, axis=-1)
    picked = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = (logz - picked) * mask
    denom = jnp.maximum(jnp.sum(mask), 1.0)
    loss = jnp.sum(nll) / denom
    total = loss + (aux_weight * aux if cfg.family == "moe" else 0.0)
    return total, {"nll": loss, "aux": aux}


def lm_prefill(params, batch, cfg, *, mesh=None, constrain: Constrain = _IDENT, context_len=None):
    """Serving prefill: consume the whole prompt, return (last-token logits,
    decode cache positioned at pos = S).  The cache layout matches
    :func:`init_decode_cache` exactly (ring-rolled for sliding windows), so
    ``lm_decode_step(params, cache, tok, pos=S, ...)`` continues seamlessly.
    ``context_len`` > S pre-allocates linear (windowless) caches for further
    decoding."""
    x = _embed_inputs(params, batch, cfg, constrain)
    B, S, D = x.shape
    positions = jnp.arange(S)
    fam = cfg.family
    w = cfg.sliding_window
    ctx = context_len or S

    if fam in ("ssm", "hybrid"):
        shared = params.get("shared_block")
        Hk, hd = cfg.num_kv_heads, cfg.resolved_head_dim
        kv_len = min(S, w) if w else S

        def body(carry, inp):
            x = carry
            lp, i = inp
            skv = {
                "k": jnp.zeros((B, kv_len, Hk, hd), x.dtype),
                "v": jnp.zeros((B, kv_len, Hk, hd), x.dtype),
            }
            if fam == "hybrid":

                def apply_shared(x):
                    h = rms_norm(x, shared["ln1"], cfg.norm_eps)
                    delta, (k, v) = attention_forward(
                        shared["attn"], h, cfg, positions, constrain, return_kv=True
                    )
                    h2 = x + delta
                    h2 = h2 + swiglu(
                        rms_norm(h2, shared["ln2"], cfg.norm_eps),
                        shared["mlp"]["w_gate"],
                        shared["mlp"]["w_up"],
                        shared["mlp"]["w_down"],
                    )
                    return h2, _kv_to_cache(k, v, w)

                x, skv = _static_cond(
                    i % cfg.shared_attn_every == 0, apply_shared, lambda x: (x, skv), x
                )
            h, c = ssm_mod.mamba2_block(
                lp["mamba"], rms_norm(x, lp["ln"], cfg.norm_eps), cfg, constrain=constrain, return_cache=True
            )
            return constrain(x + h, "act"), (c, skv)

        x, (ssm_cache, site_kv) = _layer_scan(
            body, x, params["layers"], cfg.num_layers, cfg.scan_unroll
        )
        cache = {"ssm": ssm_cache}
        if fam == "hybrid":
            cache["shared_kv"] = jax.tree.map(lambda a: a[:: cfg.shared_attn_every], site_kv)
    else:

        def body(x, inp):
            lp, _ = inp
            delta, (k, v) = attention_forward(
                lp["attn"], rms_norm(x, lp["ln1"], cfg.norm_eps), cfg, positions, constrain, return_kv=True
            )
            h = constrain(x + delta, "act")
            hin = rms_norm(h, lp["ln2"], cfg.norm_eps)
            if fam == "moe":
                d, _ = moe_block(lp["moe"], hin, cfg, mesh=mesh)
            else:
                d = swiglu(
                    hin,
                    _wc(lp["mlp"]["w_gate"], "w_col", cfg, constrain, hin.dtype),
                    _wc(lp["mlp"]["w_up"], "w_col", cfg, constrain, hin.dtype),
                    _wc(lp["mlp"]["w_down"], "w_row", cfg, constrain, hin.dtype),
                )
            return constrain(h + d, "act"), _kv_to_cache(k, v, w)

        x, kv = _layer_scan(body, x, params["layers"], cfg.num_layers, cfg.scan_unroll)
        cache = {"kv": kv}

    x = rms_norm(x[:, -1:], params["final_norm"], cfg.norm_eps)
    logits = jnp.einsum(
        "bsd,vd->bsv",
        x,
        _wc(params["embed"], "w_embed", cfg, constrain, x.dtype),
        preferred_element_type=jnp.float32,
    )
    return constrain(logits, "logits"), cache


# ================================================================= decode


def init_decode_cache(cfg, batch: int, context_len: int, dtype=None) -> dict:
    """Build the serve-time cache for ``context_len`` logical context.

    Attention caches are ``min(context_len, window)`` long (ring buffer when
    a sliding window is set); SSM layers carry O(1) state.  The cache also
    tracks nothing else — the position is an explicit argument so the same
    compiled step serves any position."""
    dtype = dtype or cfg.activation_dtype
    Hk, hd, L = cfg.num_kv_heads, cfg.resolved_head_dim, cfg.num_layers
    w = cfg.sliding_window
    kv_len = min(context_len, w) if w else context_len

    def kv(n):
        return {
            "k": jnp.zeros((n, batch, kv_len, Hk, hd), dtype),
            "v": jnp.zeros((n, batch, kv_len, Hk, hd), dtype),
        }

    fam = cfg.family
    if fam == "ssm":
        caches = [ssm_mod.init_mamba2_cache(cfg, batch, dtype) for _ in range(L)]
        return {"ssm": jax.tree.map(lambda *xs: jnp.stack(xs), *caches)}
    if fam == "hybrid":
        caches = [ssm_mod.init_mamba2_cache(cfg, batch, dtype) for _ in range(L)]
        n_sites = (L + cfg.shared_attn_every - 1) // cfg.shared_attn_every
        return {
            "ssm": jax.tree.map(lambda *xs: jnp.stack(xs), *caches),
            "shared_kv": kv(n_sites),
        }
    return {"kv": kv(L)}


def lm_decode_step(params, cache, tokens, pos, cfg, *, mesh=None, constrain: Constrain = _IDENT):
    """One decode step. tokens: (B,1) int32; pos: scalar int32 (tokens
    already generated/prefilled).  Returns (logits (B,1,V) f32, new cache)."""
    x = jnp.take(params["embed"], tokens, axis=0).astype(cfg.activation_dtype)
    x = constrain(x, "act")
    B = x.shape[0]
    fam = cfg.family

    if fam in ("ssm", "hybrid"):
        shared = params.get("shared_block")
        shared_kv = cache.get("shared_kv")

        def body(carry, inp):
            x, skv = carry
            (lp, c), i = inp

            if fam == "hybrid":

                def apply_shared(args):
                    x, skv = args
                    site = i // cfg.shared_attn_every
                    site_cache = jax.tree.map(lambda a: a[site], skv)
                    delta, new_site = attention_decode(
                        shared["attn"],
                        rms_norm(x, shared["ln1"], cfg.norm_eps),
                        site_cache,
                        pos,
                        cfg,
                        constrain,
                        mesh=mesh,
                    )
                    h = x + delta
                    h2 = h + swiglu(
                        rms_norm(h, shared["ln2"], cfg.norm_eps),
                        shared["mlp"]["w_gate"],
                        shared["mlp"]["w_up"],
                        shared["mlp"]["w_down"],
                    )
                    skv = jax.tree.map(
                        lambda full, new: lax.dynamic_update_index_in_dim(full, new, site, 0),
                        skv,
                        new_site,
                    )
                    return h2, skv

                x, skv = _static_cond(
                    i % cfg.shared_attn_every == 0, apply_shared, lambda a: a, (x, skv)
                )

            y, c_new = ssm_mod.mamba2_decode(lp["mamba"], rms_norm(x[:, 0], lp["ln"], cfg.norm_eps), c, cfg)
            return (x + y[:, None], skv), c_new

        (x, shared_kv), new_ssm = _layer_scan(
            body, (x, shared_kv), (params["layers"], cache["ssm"]),
            cfg.num_layers, cfg.scan_unroll,
        )
        new_cache = {"ssm": new_ssm}
        if fam == "hybrid":
            new_cache["shared_kv"] = shared_kv
    else:

        def body(x, inp):
            (lp, c), _ = inp
            h, c_new = attention_decode(
                lp["attn"], rms_norm(x, lp["ln1"], cfg.norm_eps), c, pos, cfg, constrain, mesh=mesh
            )
            h = x + h
            hin = rms_norm(h, lp["ln2"], cfg.norm_eps)
            if fam == "moe":
                delta, _ = moe_block(lp["moe"], hin, cfg, mesh=mesh)
            else:
                delta = swiglu(
                    hin,
                    _wc(lp["mlp"]["w_gate"], "w_col", cfg, constrain, hin.dtype),
                    _wc(lp["mlp"]["w_up"], "w_col", cfg, constrain, hin.dtype),
                    _wc(lp["mlp"]["w_down"], "w_row", cfg, constrain, hin.dtype),
                )
            return constrain(h + delta, "act"), c_new

        x, new_kv = _layer_scan(
            body, x, (params["layers"], cache["kv"]), cfg.num_layers, cfg.scan_unroll
        )
        new_cache = {"kv": new_kv}

    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = jnp.einsum(
        "bsd,vd->bsv",
        x,
        _wc(params["embed"], "w_embed", cfg, constrain, x.dtype),
        preferred_element_type=jnp.float32,
    )
    return constrain(logits, "logits"), new_cache
