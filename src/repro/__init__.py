"""repro: Distributed Flexible Nonlinear Tensor Factorization (NIPS 2016) on JAX/TPU.

Layers:
  repro.core         -- the paper's contribution (GP factorization, tight ELBOs,
                        key-value-free distributed inference)
  repro.data         -- sparse tensor store, samplers, synthetic datasets
  repro.optim        -- Adam / SGD / L-BFGS, schedules
  repro.checkpoint   -- pytree checkpointing
  repro.models       -- assigned architecture zoo (dense / MoE / SSM / hybrid /
                        audio / VLM decoder backbones)
  repro.configs      -- architecture + input-shape registry
  repro.kernels      -- Pallas TPU kernels (+ jnp reference oracles)
  repro.distributed  -- mesh-axis conventions, sharding rules
  repro.launch       -- mesh / dryrun / train / serve entry points
  repro.roofline     -- TPU v5e roofline accounting from compiled artifacts
"""

__version__ = "1.0.0"
