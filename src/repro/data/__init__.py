from repro.data.loader import Batch, minibatches, pad_to_multiple, token_batches
from repro.data.synthetic import (
    DATASET_SPECS, GroundTruth, make_dense_nonlinear_tensor, make_ground_truth,
    make_sparse_tensor,
)
from repro.data.tensor_store import (
    EntrySet, SparseTensor, balanced_train_test, kfold_split, random_entries,
    sample_zero_entries,
)
