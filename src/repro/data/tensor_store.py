"""Host-side sparse tensor storage and entry sampling.

The paper's central data-selection idea: because the GP covariance has no
Kronecker structure, training may use an ARBITRARY subset of tensor entries —
in particular a *balanced* set of nonzeros plus an equal number of sampled
zeros, which prevents the factorization from biasing toward the (meaningless)
zero ocean.  This module implements that selection exactly as in §6.1:

  * nonzero entries split into folds,
  * zero entries sampled uniformly from the complement of the nonzero set,
  * test-zeros and train-zeros kept disjoint.

Entries are stored COO-style: ``idx`` [nnz, K] int32 and ``vals`` [nnz].
Everything here is numpy (host); devices only ever see fixed-size batches.
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass(frozen=True)
class SparseTensor:
    dims: tuple[int, ...]
    idx: np.ndarray  # [nnz, K] int32
    vals: np.ndarray  # [nnz] float32

    def __post_init__(self):
        assert self.idx.ndim == 2 and self.idx.shape[1] == len(self.dims)
        assert self.vals.shape == (self.idx.shape[0],)

    @property
    def nnz(self) -> int:
        return self.idx.shape[0]

    @property
    def num_modes(self) -> int:
        return len(self.dims)

    @property
    def size(self) -> int:
        return int(np.prod([float(d) for d in self.dims]))

    @property
    def density(self) -> float:
        return self.nnz / float(np.prod([float(d) for d in self.dims]))

    def flat_index(self, idx: np.ndarray) -> np.ndarray:
        """Row-major linearized indices (int64; dims must fit)."""
        flat = np.zeros(idx.shape[0], np.int64)
        for k, d in enumerate(self.dims):
            flat = flat * d + idx[:, k].astype(np.int64)
        return flat


def random_entries(rng: np.random.Generator, dims: tuple[int, ...], n: int) -> np.ndarray:
    """n uniform entry indices (with replacement across the tensor)."""
    return np.stack([rng.integers(0, d, size=n) for d in dims], axis=1).astype(np.int32)


def sample_zero_entries(
    rng: np.random.Generator,
    tensor: SparseTensor,
    n: int,
    exclude_flat: np.ndarray | None = None,
    max_rounds: int = 50,
) -> np.ndarray:
    """Sample n entry indices that are NOT in the nonzero set (rejection).

    ``exclude_flat``: additional flat indices to avoid (e.g. test zeros so the
    train/test zero sets stay disjoint, as in the paper's protocol).
    """
    forbidden = set(tensor.flat_index(tensor.idx).tolist())
    if exclude_flat is not None:
        forbidden |= set(np.asarray(exclude_flat).tolist())
    out: list[np.ndarray] = []
    got = 0
    for _ in range(max_rounds):
        cand = random_entries(rng, tensor.dims, max(2 * (n - got), 1024))
        flat = tensor.flat_index(cand)
        # de-dup within the draw and against forbidden
        keep_mask = np.fromiter((f not in forbidden for f in flat), bool, len(flat))
        cand, flat = cand[keep_mask], flat[keep_mask]
        _, first = np.unique(flat, return_index=True)
        cand, flat = cand[np.sort(first)], flat[np.sort(first)]
        take = min(n - got, len(cand))
        out.append(cand[:take])
        forbidden |= set(flat[:take].tolist())
        got += take
        if got >= n:
            break
    if got < n:
        raise RuntimeError(f"could not sample {n} zero entries ({got} found); tensor too dense")
    return np.concatenate(out, axis=0)


@dataclasses.dataclass(frozen=True)
class EntrySet:
    """A labelled set of tensor entries (inputs to the GP factorization)."""

    idx: np.ndarray  # [N, K] int32
    y: np.ndarray  # [N] float32

    def __len__(self) -> int:
        return self.idx.shape[0]

    def shuffled(self, rng: np.random.Generator) -> "EntrySet":
        perm = rng.permutation(len(self))
        return EntrySet(self.idx[perm], self.y[perm])

    def concat(self, other: "EntrySet") -> "EntrySet":
        return EntrySet(
            np.concatenate([self.idx, other.idx]), np.concatenate([self.y, other.y])
        )


def kfold_split(
    rng: np.random.Generator, tensor: SparseTensor, folds: int = 5
) -> list[tuple[np.ndarray, np.ndarray]]:
    """Split nonzero entries into (train_rows, test_rows) per fold (§6.1)."""
    perm = rng.permutation(tensor.nnz)
    parts = np.array_split(perm, folds)
    out = []
    for f in range(folds):
        test = parts[f]
        train = np.concatenate([parts[g] for g in range(folds) if g != f])
        out.append((train, test))
    return out


def balanced_train_test(
    rng: np.random.Generator,
    tensor: SparseTensor,
    train_rows: np.ndarray,
    test_rows: np.ndarray,
    test_zero_fraction: float = 0.001,
    train_zero_ratio: float = 1.0,
    binary: bool = False,
) -> tuple[EntrySet, EntrySet]:
    """Paper §6.1 protocol.

    Test: the held-out nonzeros + `test_zero_fraction` of the tensor volume as
    zeros (capped at 10x the test nonzeros to keep AUC meaningful).
    Train: train nonzeros + `train_zero_ratio` x as many sampled zeros,
    disjoint from the test zeros.
    """
    n_test_zeros = int(min(tensor.size * test_zero_fraction, 10 * len(test_rows)))
    n_test_zeros = max(n_test_zeros, len(test_rows))
    test_zero_idx = sample_zero_entries(rng, tensor, n_test_zeros)
    test = EntrySet(
        np.concatenate([tensor.idx[test_rows], test_zero_idx]),
        np.concatenate(
            [
                np.ones(len(test_rows), np.float32)
                if binary
                else tensor.vals[test_rows].astype(np.float32),
                np.zeros(n_test_zeros, np.float32),
            ]
        ),
    )
    n_train_zeros = int(train_zero_ratio * len(train_rows))
    train_zero_idx = sample_zero_entries(
        rng, tensor, n_train_zeros, exclude_flat=tensor.flat_index(test_zero_idx)
    )
    train = EntrySet(
        np.concatenate([tensor.idx[train_rows], train_zero_idx]),
        np.concatenate(
            [
                np.ones(len(train_rows), np.float32)
                if binary
                else tensor.vals[train_rows].astype(np.float32),
                np.zeros(n_train_zeros, np.float32),
            ]
        ),
    )
    return train.shuffled(rng), test.shuffled(rng)
