"""Synthetic dataset generators.

The paper's datasets (Alog, AdClick, Enron, NellSmall, ACC, DBLP, NELL, and
the Yahoo CTR logs) are proprietary or not redistributable offline, so we ship
generators that reproduce each dataset's SHAPE, SPARSITY and observation type,
with a *nonlinear* ground truth so the paper's central claim — nonlinear GP
factorization beats multilinear CP/Tucker — is actually testable.

Ground truth: per-mode latent factors U*_k; entry value
    f(x) = sum_c a_c * exp(-||x - c||^2 / (2 s^2))  (random RBF mixture)
plus optional CP-style multilinear component, then Gaussian noise (continuous)
or a Probit threshold (binary).  The RBF mixture is exactly the function class
a GP with RBF kernel models well but a multilinear model cannot represent.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.data.tensor_store import SparseTensor, random_entries

# (dims, nonzero density, binary?) replicating Table/§6 descriptions.
DATASET_SPECS: dict[str, tuple[tuple[int, ...], float, bool]] = {
    "alog": ((200, 100, 200), 0.0033, False),
    "adclick": ((80, 100, 100), 0.0239, False),
    "enron": ((203, 203, 200), 0.0001, True),
    "nellsmall": ((295, 170, 94), 0.0005, True),
    "acc": ((3000, 150, 30000), 9e-5, False),
    "dblp": ((10000, 200, 10000), 1e-5, True),
    "nell": ((20000, 12300, 280), 1e-6, True),
    # one day of the CTR tensor.  Mode sizes scaled ~90x from the paper's
    # 179K x 81K x 35 x 355; density scaled UP so per-user/ad click coverage
    # matches the paper's (~0.6-5 clicks/row) — preserving raw density at
    # reduced dims would leave every user factor untrained (cold-start
    # artifact of downscaling, not of the model).
    "ctr_day": ((2000, 1000, 35, 355), 4e-7, True),
}


@dataclasses.dataclass(frozen=True)
class GroundTruth:
    factors: tuple[np.ndarray, ...]  # per-mode [d_k, r]
    centers: np.ndarray  # [C, K*r]
    weights: np.ndarray  # [C]
    bandwidth: float
    cp_weight: float  # weight of the additive multilinear component
    noise_std: float

    def latent(self, idx: np.ndarray) -> np.ndarray:
        xs = np.concatenate([self.factors[k][idx[:, k]] for k in range(len(self.factors))], 1)
        d2 = ((xs[:, None, :] - self.centers[None, :, :]) ** 2).sum(-1)
        f = (np.exp(-0.5 * d2 / self.bandwidth**2) * self.weights[None, :]).sum(-1)
        if self.cp_weight:
            r = self.factors[0].shape[1]
            prod = np.ones((idx.shape[0], r))
            for k in range(len(self.factors)):
                prod = prod * self.factors[k][idx[:, k]]
            f = f + self.cp_weight * prod.sum(-1)
        return f


def make_ground_truth(
    rng: np.random.Generator,
    dims: tuple[int, ...],
    rank: int = 3,
    num_centers: int = 12,
    bandwidth: float = 2.0,
    cp_weight: float = 0.3,
    noise_std: float = 0.05,
) -> GroundTruth:
    factors = tuple(rng.normal(size=(d, rank)) * 0.8 for d in dims)
    input_dim = rank * len(dims)
    return GroundTruth(
        factors=factors,
        centers=rng.normal(size=(num_centers, input_dim)),
        weights=rng.normal(size=num_centers),
        bandwidth=bandwidth,
        cp_weight=cp_weight,
        noise_std=noise_std,
    )


def _dedup(dims, idx):
    flat = np.zeros(idx.shape[0], np.int64)
    for k, d in enumerate(dims):
        flat = flat * d + idx[:, k]
    _, first = np.unique(flat, return_index=True)
    return idx[np.sort(first)]


def make_sparse_tensor(
    name: str,
    seed: int = 0,
    rank: int = 3,
    max_nnz: int | None = None,
    dim_scale: float = 1.0,
) -> tuple[SparseTensor, GroundTruth]:
    """Generate a sparse observed tensor with the named dataset's footprint.

    ``dim_scale`` < 1 shrinks every mode proportionally while KEEPING the
    dataset's density — the CPU-budget way to downsize.  (Capping nnz alone
    makes the tensor unrealistically sparse: most factor rows end up with
    zero observations and every model degenerates to the zero predictor.)
    """
    if name not in DATASET_SPECS:
        raise KeyError(f"unknown dataset {name!r}; known: {sorted(DATASET_SPECS)}")
    dims, density, binary = DATASET_SPECS[name]
    if dim_scale != 1.0:
        dims = tuple(max(int(d * dim_scale), 10) for d in dims)
    rng = np.random.default_rng(seed)
    truth = make_ground_truth(rng, dims, rank=rank)
    size = float(np.prod([float(d) for d in dims]))
    nnz = int(size * density)
    if max_nnz is not None:
        nnz = min(nnz, max_nnz)
    nnz = max(nnz, 100)
    if binary:
        # knowledge-base style: nonzeros are the entries where the latent
        # function is largest (otherwise positions would be structureless
        # noise and nothing could be learned from them)
        cand = _dedup(dims, random_entries(rng, dims, int(nnz * 6)))
        f_cand = truth.latent(cand)
        keep = np.argsort(-f_cand)[:nnz]
        idx = cand[keep].astype(np.int32)
        vals = np.ones(len(idx), np.float32)
        return SparseTensor(dims=dims, idx=idx, vals=vals), truth
    idx = _dedup(dims, random_entries(rng, dims, int(nnz * 1.2)))[:nnz].astype(np.int32)
    f = truth.latent(idx)
    vals = (f + rng.normal(size=len(f)) * truth.noise_std).astype(np.float32)
    # keep "nonzero" semantics: shift so stored values are bounded away from 0
    vals = vals + np.sign(vals + 1e-9) * 0.1
    return SparseTensor(dims=dims, idx=idx, vals=vals.astype(np.float32)), truth


def make_dense_nonlinear_tensor(
    rng: np.random.Generator, dims: tuple[int, ...], rank: int = 3, noise_std: float = 0.05
) -> tuple[np.ndarray, GroundTruth]:
    """Small fully-observed tensor for exactness tests / InfTucker baseline."""
    truth = make_ground_truth(rng, dims, rank=rank, noise_std=noise_std)
    grid = np.stack(np.meshgrid(*[np.arange(d) for d in dims], indexing="ij"), -1)
    idx = grid.reshape(-1, len(dims))
    f = truth.latent(idx) + rng.normal(size=idx.shape[0]) * noise_std
    return f.reshape(dims).astype(np.float32), truth
