"""Fixed-size, shard-able batch iteration over entry sets.

Under shard_map every device must receive an equal-size slice, so batches are
padded with zero-WEIGHT entries (the statistics in core/stats.py are weighted
sums; w=0 rows contribute nothing — verified by test_zero_weight_padding).
"""
from __future__ import annotations

import dataclasses
from typing import Iterator

import numpy as np

from repro.data.tensor_store import EntrySet


@dataclasses.dataclass(frozen=True)
class Batch:
    idx: np.ndarray  # [B, K] int32
    y: np.ndarray  # [B] float32
    w: np.ndarray  # [B] float32 (0 = padding)


def pad_to_multiple(entries: EntrySet, multiple: int) -> Batch:
    """Whole-dataset batch padded so len % multiple == 0 (full-batch training,
    as in the paper's L-BFGS/GD setting)."""
    n = len(entries)
    padded = ((n + multiple - 1) // multiple) * multiple
    pad = padded - n
    idx = np.concatenate([entries.idx, np.zeros((pad, entries.idx.shape[1]), np.int32)])
    y = np.concatenate([entries.y, np.zeros(pad, np.float32)])
    w = np.concatenate([np.ones(n, np.float32), np.zeros(pad, np.float32)])
    return Batch(idx=idx.astype(np.int32), y=y.astype(np.float32), w=w)


def token_batches(cfg, batch_size: int, seq_len: int, seed: int = 0) -> Iterator[dict]:
    """Synthetic LM token stream for the model-zoo trainers.

    Tokens follow a noisy affine recurrence x[t+1] = (a*x[t] + c) % V with 10%
    uniform corruption — a next-token structure any of the zoo architectures
    can learn (loss visibly decreases within tens of steps), with enough
    entropy that it cannot be memorized from the embedding alone.
    For VLM configs the batch also carries random patch embeddings and the
    text span is shortened so text + frontend tokens == seq_len.
    """
    import jax.numpy as jnp  # local: keep module importable without jax

    rng = np.random.default_rng(seed)
    V = cfg.vocab_size
    a, c = 31 % V or 1, 7 % V
    text_len = seq_len - (cfg.frontend_tokens if cfg.modality == "vision" else 0)
    while True:
        x0 = rng.integers(0, V, size=(batch_size, 1))
        xs = [x0]
        for _ in range(text_len):
            nxt = (a * xs[-1] + c) % V
            corrupt = rng.random((batch_size, 1)) < 0.1
            nxt = np.where(corrupt, rng.integers(0, V, size=(batch_size, 1)), nxt)
            xs.append(nxt)
        toks = np.concatenate(xs, axis=1)  # (B, text_len+1)
        batch = {
            "tokens": jnp.asarray(toks[:, :text_len], jnp.int32),
        }
        if cfg.modality == "vision":
            patches = rng.normal(size=(batch_size, cfg.frontend_tokens, 1024)) * 0.02
            batch["patch_embeds"] = jnp.asarray(patches, jnp.bfloat16)
            labels = np.concatenate(
                [np.zeros((batch_size, cfg.frontend_tokens), np.int64), toks[:, 1 : text_len + 1]], 1
            )
            mask = np.concatenate(
                [np.zeros((batch_size, cfg.frontend_tokens)), np.ones((batch_size, text_len))], 1
            )
        else:
            labels = toks[:, 1 : text_len + 1]
            mask = np.ones((batch_size, text_len))
        batch["labels"] = jnp.asarray(labels, jnp.int32)
        batch["mask"] = jnp.asarray(mask, jnp.float32)
        yield batch


def minibatches(
    entries: EntrySet, batch_size: int, rng: np.random.Generator, epochs: int | None = None
) -> Iterator[Batch]:
    """Shuffled fixed-size minibatches, final partial batch zero-weight padded."""
    epoch = 0
    while epochs is None or epoch < epochs:
        shuffled = entries.shuffled(rng)
        for start in range(0, len(shuffled), batch_size):
            stop = min(start + batch_size, len(shuffled))
            sl = EntrySet(shuffled.idx[start:stop], shuffled.y[start:stop])
            yield pad_to_multiple(sl, batch_size)
        epoch += 1
