"""jit'd wrapper around the fused gram kernel: padding, scaling, masking, VJP.

``gram_stats`` is the drop-in 'pallas' backend for core/stats (same contract
as the jnp path, verified against ref.py).  Details:

  * P (inducing) is padded to the TPU lane width (128) and masked;
    N (entries) is padded to the tile size with zero-weight rows.
  * Pallas kernels are not auto-differentiable, so gram_stats carries a
    custom VJP whose backward pass is the jax.vjp of the pure-jnp reference
    (recompute; same statistics, so gradients are exact).  The fused forward
    is what the inference hot paths need most — the lambda fixed-point loop
    and prediction are forward-only.
  * On non-TPU backends the kernel runs in interpret mode (Python emulation)
    so the whole path is testable on CPU.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.core import gp
from repro.core.stats import SuffStats
from repro.kernels.gp_gram import ref
from repro.kernels.gp_gram.kernel import gram_pallas_call

LANE = 128


def _pad_to(x: jax.Array, size: int, axis: int) -> jax.Array:
    pad = size - x.shape[axis]
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


def _round_up(v: int, m: int) -> int:
    return ((v + m - 1) // m) * m


def _forward(kind, tile_n, interpret, kp, xs, bs, y, w, whiten_inv) -> SuffStats:
    n, d = xs.shape
    p = bs.shape[0]
    dtype = xs.dtype

    # lengthscale scaling happens outside the kernel (fuses into the gather);
    # amplitude^2 is a traced (1,1) input.
    ls = kp.lengthscale
    xs_s = (xs / ls).astype(dtype)
    bs_s = (bs / ls).astype(dtype)
    kdiag = gp.kernel_diag(kind, kp, xs)
    amp2 = jnp.reshape(kp.amplitude2, (1, 1)).astype(dtype)

    p_pad = _round_up(p, LANE)
    n_pad = _round_up(n, tile_n)
    tile = min(tile_n, n_pad)

    xs_s = _pad_to(xs_s, n_pad, 0)
    x2 = jnp.sum(xs_s * xs_s, axis=1, keepdims=True)
    bs_p = _pad_to(bs_s, p_pad, 0)
    b2 = jnp.sum(bs_p * bs_p, axis=1)[None, :]
    y_p = _pad_to(y.astype(dtype)[:, None], n_pad, 0)
    w_p = _pad_to(w.astype(dtype)[:, None], n_pad, 0)
    kd_p = _pad_to(kdiag.astype(dtype)[:, None], n_pad, 0)
    mask = (jnp.arange(p_pad) < p).astype(dtype)[None, :]
    if whiten_inv is not None:
        wmat = _pad_to(_pad_to(whiten_inv.astype(dtype), p_pad, 0), p_pad, 1)
        wmat = wmat + jnp.diag((jnp.arange(p_pad) >= p).astype(dtype))
    else:
        wmat = jnp.eye(p_pad, dtype=dtype)

    call = gram_pallas_call(n_pad, p_pad, d, tile, kind, interpret)
    a1, a2, a3, a4, n_out = call(
        xs_s, x2, bs_p, b2, y_p, w_p, kd_p, mask, wmat, amp2
    )
    return SuffStats(
        a1=a1[:p, :p].astype(dtype),
        a2=a2[0, 0].astype(dtype),
        a3=a3[0, 0].astype(dtype),
        a4=a4[0, :p].astype(dtype),
        n=n_out[0, 0].astype(dtype),
    )


@partial(jax.custom_vjp, nondiff_argnums=(0, 1, 2))
def _gram_stats(kind, tile_n, interpret, kp, xs, bs, y, w, whiten_inv):
    return _forward(kind, tile_n, interpret, kp, xs, bs, y, w, whiten_inv)


def _gram_fwd(kind, tile_n, interpret, kp, xs, bs, y, w, whiten_inv):
    out = _forward(kind, tile_n, interpret, kp, xs, bs, y, w, whiten_inv)
    return out, (kp, xs, bs, y, w, whiten_inv)


def _gram_bwd(kind, tile_n, interpret, residuals, ct: SuffStats):
    kp, xs, bs, y, w, whiten_inv = residuals
    _, vjp = jax.vjp(
        lambda kp_, xs_, bs_, y_, w_, wi_: ref.gram_stats_ref(
            kind, kp_, xs_, bs_, y_, w_, wi_
        ),
        kp, xs, bs, y, w, whiten_inv,
    )
    return vjp(ct)


_gram_stats.defvjp(_gram_fwd, _gram_bwd)


def gram_stats(
    kind: str,
    kp: gp.KernelParams,
    xs: jax.Array,
    bs: jax.Array,
    y: jax.Array,
    w: jax.Array,
    whiten_inv: jax.Array | None = None,
    *,
    tile_n: int = 512,
    interpret: bool | None = None,
) -> SuffStats:
    """Fused SuffStats for ALREADY-GATHERED inputs xs [N, D]."""
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    return _gram_stats(kind, tile_n, bool(interpret), kp, xs, bs, y, w, whiten_inv)
