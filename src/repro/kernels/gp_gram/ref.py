"""Pure-jnp oracle for the fused gram kernel (shared with core/stats)."""
from __future__ import annotations

import jax

from repro.core import gp
from repro.core.stats import SuffStats, _chunk_stats_jnp


def gram_stats_ref(
    kind: str,
    kp: gp.KernelParams,
    xs: jax.Array,
    bs: jax.Array,
    y: jax.Array,
    w: jax.Array,
    whiten_inv: jax.Array | None = None,
) -> SuffStats:
    """Reference: materialize K_xB, then reduce.  The semantics the Pallas
    kernel must reproduce (up to f32 reassociation)."""
    return _chunk_stats_jnp(kind, kp, xs, bs, y, w, whiten_inv)
