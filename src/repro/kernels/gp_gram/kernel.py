"""Fused ARD/RBF cross-covariance + gram-accumulation Pallas TPU kernel.

The paper's per-mapper hot loop is: for each tensor entry j, compute the
p-vector k(B, x_j) and accumulate A1 += k k^T, a4 += k y_j, a3 += k(x_j,x_j).
A naive implementation materializes K_SB (N x p) in HBM and then runs a GEMM
— 2x HBM traffic on the largest intermediate.  This kernel re-blocks the loop
for the TPU memory hierarchy:

  HBM -> VMEM : one (TN x D) tile of scaled inputs per grid step
  MXU         : cross = tile @ B^T          (TN x P)
  VPU         : r2 -> correlation -> k      (elementwise, in VMEM)
  MXU         : k = k @ W^T                 (optional feature whitening)
  MXU         : A1 += k^T (w * k);  a4 += k^T (w y)

K_SB never exists in HBM; the only HBM traffic is the input tile stream and
the fixed-size (P x P) accumulators.  Accumulation across grid steps uses the
revisiting-output pattern (all steps map to output block (0, 0)), with f32
accumulators regardless of the input dtype.

Weights w encode zero-padding (w=0 rows contribute nothing), so callers can
pad N up to the tile size with no semantic change.  A column mask kills
padded inducing columns (P is padded to the lane width, 128).  The kernel
amplitude amp^2 is a traced (1,1) scalar input so hyper-parameter training
does not recompile.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _dot_f32(a, b):
    return jax.lax.dot_general(
        a, b, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )


def _correlation(kind: str, r2):
    if kind in ("rbf", "ard"):
        return jnp.exp(-0.5 * r2)
    r = jnp.sqrt(r2 + 1e-12)
    if kind == "matern32":
        s = jnp.sqrt(3.0).astype(r.dtype) * r
        return (1.0 + s) * jnp.exp(-s)
    if kind == "matern52":
        s = jnp.sqrt(5.0).astype(r.dtype) * r
        return (1.0 + s + s * s / 3.0) * jnp.exp(-s)
    raise ValueError(f"unsupported kernel kind {kind!r}")


def _gram_kernel(
    # inputs (VMEM refs)
    xs_ref,  # [TN, D]   scaled inputs tile
    x2_ref,  # [TN, 1]   per-row squared norm
    bs_ref,  # [P, D]    scaled inducing points (replicated each step)
    b2_ref,  # [1, P]    per-inducing squared norm
    y_ref,  # [TN, 1]
    w_ref,  # [TN, 1]
    kd_ref,  # [TN, 1]   kernel diagonal k(x, x)
    mask_ref,  # [1, P]  1 for real inducing columns, 0 for padding
    wmat_ref,  # [P, P]  whitening matrix W (k <- k @ W^T); identity if unused
    amp2_ref,  # [1, 1]  kernel amplitude^2 (traced hyper-parameter)
    # outputs (accumulated across grid steps)
    a1_ref,  # [P, P]
    a2_ref,  # [1, 1]
    a3_ref,  # [1, 1]
    a4_ref,  # [1, P]
    n_ref,  # [1, 1]
    *,
    kind: str,
):
    step = pl.program_id(0)

    @pl.when(step == 0)
    def _init():
        a1_ref[...] = jnp.zeros_like(a1_ref)
        a2_ref[...] = jnp.zeros_like(a2_ref)
        a3_ref[...] = jnp.zeros_like(a3_ref)
        a4_ref[...] = jnp.zeros_like(a4_ref)
        n_ref[...] = jnp.zeros_like(n_ref)

    xs = xs_ref[...]
    bs = bs_ref[...]
    w = w_ref[...].astype(jnp.float32)  # [TN, 1]
    y = y_ref[...].astype(jnp.float32)
    amp2 = amp2_ref[0, 0].astype(jnp.float32)

    if kind == "linear":
        k = amp2 * _dot_f32(xs, bs.T)
    else:
        cross = _dot_f32(xs, bs.T)  # [TN, P] f32
        r2 = (
            x2_ref[...].astype(jnp.float32)
            + b2_ref[...].astype(jnp.float32)
            - 2.0 * cross
        )
        r2 = jnp.maximum(r2, 0.0)
        k = amp2 * _correlation(kind, r2)
    k = k * mask_ref[...].astype(jnp.float32)  # kill padded inducing columns
    k = _dot_f32(k, wmat_ref[...].astype(jnp.float32).T)  # optional whitening
    kw = k * w  # [TN, P]

    a1_ref[...] += _dot_f32(k.T, kw)
    a4_ref[...] += _dot_f32((y * w).T, k)  # [1, P]
    a2_ref[...] += jnp.sum(w * y * y).reshape(1, 1)
    a3_ref[...] += jnp.sum(w * kd_ref[...].astype(jnp.float32)).reshape(1, 1)
    n_ref[...] += jnp.sum(w).reshape(1, 1)


def gram_pallas_call(n: int, p: int, d: int, tile_n: int, kind: str, interpret: bool):
    """Build the pallas_call for given static shapes."""
    grid = (n // tile_n,)
    f32 = jnp.float32
    return pl.pallas_call(
        functools.partial(_gram_kernel, kind=kind),
        grid=grid,
        in_specs=[
            pl.BlockSpec((tile_n, d), lambda i: (i, 0)),  # xs
            pl.BlockSpec((tile_n, 1), lambda i: (i, 0)),  # x2
            pl.BlockSpec((p, d), lambda i: (0, 0)),  # bs
            pl.BlockSpec((1, p), lambda i: (0, 0)),  # b2
            pl.BlockSpec((tile_n, 1), lambda i: (i, 0)),  # y
            pl.BlockSpec((tile_n, 1), lambda i: (i, 0)),  # w
            pl.BlockSpec((tile_n, 1), lambda i: (i, 0)),  # kdiag
            pl.BlockSpec((1, p), lambda i: (0, 0)),  # mask
            pl.BlockSpec((p, p), lambda i: (0, 0)),  # whitening matrix
            pl.BlockSpec((1, 1), lambda i: (0, 0)),  # amp2
        ],
        out_specs=[
            pl.BlockSpec((p, p), lambda i: (0, 0)),
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
            pl.BlockSpec((1, p), lambda i: (0, 0)),
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((p, p), f32),
            jax.ShapeDtypeStruct((1, 1), f32),
            jax.ShapeDtypeStruct((1, 1), f32),
            jax.ShapeDtypeStruct((1, p), f32),
            jax.ShapeDtypeStruct((1, 1), f32),
        ],
        interpret=interpret,
    )
