"""Blocked (flash) attention Pallas TPU kernel, causal + sliding-window.

TPU re-blocking of the attention hot loop (the model zoo's prefill/train
compute peak).  The (S x S) score matrix never exists in HBM:

  HBM -> VMEM : one (Bq x hd) query block; (Bk x hd) K/V blocks stream
  MXU         : s = q @ k^T                  (Bq x Bk)
  VPU         : online softmax (running max m, normalizer l, rescale)
  MXU         : acc += p @ v                 (Bq x hd)

Grid is (batch, q_heads, q_blocks, kv_blocks) with kv innermost; the output
block is revisited across the kv dimension (standard accumulation pattern)
with f32 scratch accumulators.  Causal/sliding-window blocks that are fully
masked are skipped with ``pl.when`` — the MXU never sees them, so SWA cost
is O(S*W) like the jnp oracle.

GQA folds the q-head -> kv-head mapping into the K/V index_map (h // group),
so kv blocks are fetched once per group from the same HBM buffer.

Layout: (B, H, S, hd) — heads-major so a block is a contiguous (S, hd) tile.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _attn_kernel(
    q_ref,  # [1, 1, Bq, hd]
    k_ref,  # [1, 1, Bk, hd]
    v_ref,  # [1, 1, Bk, hd]
    o_ref,  # [1, 1, Bq, hd]
    m_ref,  # scratch [Bq, 1] f32 running max
    l_ref,  # scratch [Bq, 1] f32 running normalizer
    acc_ref,  # scratch [Bq, hd] f32
    *,
    causal: bool,
    window: int,
    block_q: int,
    block_kv: int,
    sm_scale: float,
    kv_blocks: int,
):
    iq = pl.program_id(2)
    ik = pl.program_id(3)

    @pl.when(ik == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q_start = iq * block_q
    k_start = ik * block_kv
    # block-level reachability: any (qpos >= kpos) and (qpos - kpos < window)?
    reachable = True
    if causal:
        reachable = q_start + block_q - 1 >= k_start
    if window > 0:
        reachable = jnp.logical_and(
            reachable, (q_start - (k_start + block_kv - 1)) < window
        )

    @pl.when(reachable)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)
        k = k_ref[0, 0].astype(jnp.float32)
        v = v_ref[0, 0].astype(jnp.float32)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        ) * sm_scale  # [Bq, Bk]
        qpos = q_start + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_kv), 0)
        kpos = k_start + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_kv), 1)
        mask = jnp.ones((block_q, block_kv), jnp.bool_)
        if causal:
            mask &= qpos >= kpos
        if window > 0:
            mask &= (qpos - kpos) < window
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_ref[...]  # [Bq, 1]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
        p = jnp.exp(s - m_new)  # fully-masked rows: exp(NEG_INF - NEG_INF)=1 guarded below
        p = jnp.where(mask, p, 0.0)
        alpha = jnp.exp(m_prev - m_new)  # [Bq, 1]
        l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=1, keepdims=True)
        acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
        )
        m_ref[...] = m_new

    @pl.when(ik == kv_blocks - 1)
    def _finalize():
        l = l_ref[...]
        norm = jnp.where(l > 0.0, 1.0 / jnp.maximum(l, 1e-30), 0.0)
        o_ref[0, 0, :, :] = (acc_ref[...] * norm).astype(o_ref.dtype)


def flash_attention_bhsd(
    q: jax.Array,  # (B, H, S, hd)
    k: jax.Array,  # (B, Hk, S, hd)
    v: jax.Array,
    *,
    causal: bool = True,
    window: int = 0,
    block_q: int = 128,
    block_kv: int = 128,
    interpret: bool = False,
) -> jax.Array:
    B, H, S, hd = q.shape
    Hk = k.shape[1]
    assert H % Hk == 0, (H, Hk)
    group = H // Hk
    block_q = min(block_q, S)
    block_kv = min(block_kv, S)
    assert S % block_q == 0 and S % block_kv == 0, (S, block_q, block_kv)
    nq, nk = S // block_q, S // block_kv

    kernel = functools.partial(
        _attn_kernel,
        causal=causal,
        window=window,
        block_q=block_q,
        block_kv=block_kv,
        sm_scale=1.0 / math.sqrt(hd),
        kv_blocks=nk,
    )
    return pl.pallas_call(
        kernel,
        grid=(B, H, nq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, block_q, hd), lambda b, h, iq, ik: (b, h, iq, 0)),
            pl.BlockSpec((1, 1, block_kv, hd), lambda b, h, iq, ik: (b, h // group, ik, 0)),
            pl.BlockSpec((1, 1, block_kv, hd), lambda b, h, iq, ik: (b, h // group, ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, hd), lambda b, h, iq, ik: (b, h, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, S, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, hd), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
