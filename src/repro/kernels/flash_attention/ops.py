"""jit'd public wrapper for the flash attention kernel.

Accepts the model-zoo layout (B, S, H, hd), transposes to the kernel's
heads-major layout, pads the sequence up to the block size, and dispatches
to either the Pallas kernel (TPU target; interpret=True executes the kernel
body in Python on CPU for validation) or the jnp oracle.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention.kernel import flash_attention_bhsd
from repro.kernels.flash_attention.ref import attention_ref


def _round_up(v: int, m: int) -> int:
    return (v + m - 1) // m * m


@partial(
    jax.jit,
    static_argnames=("causal", "window", "block_q", "block_kv", "interpret", "use_ref"),
)
def flash_attention(
    q: jax.Array,  # (B, S, H, hd)
    k: jax.Array,  # (B, S, Hk, hd)
    v: jax.Array,
    *,
    causal: bool = True,
    window: int = 0,
    block_q: int = 128,
    block_kv: int = 128,
    interpret: bool = False,
    use_ref: bool = False,
) -> jax.Array:
    B, S, H, hd = q.shape
    qt = q.transpose(0, 2, 1, 3)
    kt = k.transpose(0, 2, 1, 3)
    vt = v.transpose(0, 2, 1, 3)
    if use_ref:
        out = attention_ref(qt, kt, vt, causal=causal, window=window)
        return out.transpose(0, 2, 1, 3)

    Sp = _round_up(S, max(block_q, block_kv))
    if Sp != S:
        pad = [(0, 0), (0, 0), (0, Sp - S), (0, 0)]
        qt, kt, vt = (jnp.pad(t, pad) for t in (qt, kt, vt))
    out = flash_attention_bhsd(
        qt, kt, vt,
        causal=causal, window=window,
        block_q=block_q, block_kv=block_kv, interpret=interpret,
    )
    return out[:, :, :S].transpose(0, 2, 1, 3)
