"""Pure-jnp oracle for the flash attention kernel (naive masked softmax)."""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def attention_ref(q, k, v, *, causal: bool = True, window: int = 0) -> jax.Array:
    """q: (B,H,S,hd); k/v: (B,Hk,S,hd) -> (B,H,S,hd).  f32 softmax."""
    B, H, S, hd = q.shape
    Hk = k.shape[1]
    g = H // Hk
    qf = q.astype(jnp.float32).reshape(B, Hk, g, S, hd)
    s = jnp.einsum("bhgqd,bhkd->bhgqk", qf, k.astype(jnp.float32)) / math.sqrt(hd)
    i, j = jnp.arange(S)[:, None], jnp.arange(S)[None, :]
    mask = jnp.ones((S, S), bool)
    if causal:
        mask &= i >= j
    if window > 0:
        mask &= i - j < window
    s = jnp.where(mask, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    # fully-masked rows (can't happen causally, but keep the oracle total)
    p = jnp.where(mask.any(axis=-1)[None, None, None, :, None], p, 0.0)
    o = jnp.einsum("bhgqk,bhkd->bhgqd", p, v.astype(jnp.float32))
    return o.reshape(B, H, S, hd).astype(q.dtype)
