"""Collective-byte accounting from post-SPMD HLO text.

``compiled.cost_analysis()`` has no collective figures, so we parse the
optimized per-device HLO module: every ``all-gather / all-reduce /
reduce-scatter / all-to-all / collective-permute`` op contributes its
RESULT shape's bytes (for async ``*-start`` ops the result is a tuple —
we take the largest element; the paired ``*-done`` is skipped).

The shapes in the post-partitioning module are PER-DEVICE shard shapes, so
the sum is bytes-moved-per-chip; the roofline collective term is then
``per_chip_bytes * multiplier / link_bw``, with the standard ring factors:
all-reduce counts 2x (reduce-scatter + all-gather phases); everything else
1x.  (The (n-1)/n ring factor is folded to 1 — a <7% correction at n>=16.)
"""
from __future__ import annotations

import re
from collections import defaultdict

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "s32": 4, "u32": 4, "s64": 8, "u64": 8, "f8e4m3fn": 1, "f8e5m2": 1,
    "bf16": 2, "f16": 2, "f32": 4, "f64": 8, "c64": 8, "c128": 16,
    "token": 0,
}

_COLLECTIVES = (
    "all-gather",
    "all-reduce",
    "reduce-scatter",
    "all-to-all",
    "collective-permute",
)

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
# "  %name = <result-shape(s)> op-name(" — op name right before the open paren
_OP_RE = re.compile(
    r"=\s*(.*?)\s+(" + "|".join(_COLLECTIVES) + r")(-start)?\("
)


def _shape_bytes(text: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(text):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def collective_bytes(hlo_text: str) -> dict[str, int]:
    """Per-chip bytes by collective kind (result-shape accounting)."""
    out: dict[str, int] = defaultdict(int)
    for line in hlo_text.splitlines():
        m = _OP_RE.search(line)
        if not m:
            continue
        result_types, kind, _ = m.groups()
        out[kind] += _shape_bytes(result_types)
    return dict(out)


def collective_link_bytes(by_kind: dict[str, int]) -> float:
    """Ring-model bytes that actually cross a link, per chip."""
    total = 0.0
    for kind, b in by_kind.items():
        total += 2.0 * b if kind == "all-reduce" else float(b)
    return total
