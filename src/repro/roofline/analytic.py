"""Analytic FLOP / HBM-byte model for the roofline terms.

WHY ANALYTIC: XLA's HloCostAnalysis counts each ``while`` body ONCE,
regardless of trip count.  Our production programs are loops at three levels
(microbatches, layers, attention/SSD chunks), so ``compiled.cost_analysis()``
under-reports by the product of the trip counts.  Unrolling everything makes
the cost analysis exact but is compile-time-prohibitive at the full scale
(52-80 layers x 64+ attention chunks x 512 partitions, single build CPU).

So: FLOPs and HBM bytes come from this closed-form model of EXACTLY the
schedule the model code executes (same chunk counts, same causal block
skipping, same MoE capacity, same remat policy), and the model is VALIDATED
against ``cost_analysis()`` on shrunken configs compiled with every loop
unrolled (tests/test_roofline.py + EXPERIMENTS.md §Dry-run methodology).
Collective bytes and the memory footprint stay HLO-derived (loop-free after
layer-probe extrapolation / reported by memory_analysis directly).

Conventions:
  * only matmul/conv FLOPs (2mnk) are counted — elementwise/softmax terms
    are O(1/hd) relative and are in the validation tolerance;
  * train factor per op: fwd 2mnk + bwd 4mnk + remat-recompute 2mnk = 4x the
    fwd cost for everything inside a checkpointed layer, 3x outside (no
    recompute: embedding/logits/loss);
  * HBM bytes model: parameter traffic (FSDP-gathered per use, f32 master),
    optimizer state traffic, activation tile streams of the chunked
    attention/SSD schedules, logits, and KV-cache/state traffic at decode.
"""
from __future__ import annotations

import dataclasses
import math

from repro.configs import ArchConfig, ShapeConfig

ACT_BYTES = 2  # bf16 activations
P_BYTES = 4  # f32 master params


@dataclasses.dataclass
class OpCounts:
    flops: float = 0.0
    hbm_bytes: float = 0.0

    def __add__(self, o):
        return OpCounts(self.flops + o.flops, self.hbm_bytes + o.hbm_bytes)

    def scale(self, f):
        return OpCounts(self.flops * f, self.hbm_bytes * f)


def _attention_tiles(S: int, qc: int, kc: int, window: int, causal: bool = True) -> int:
    """Number of (qc x kc) tiles the chunked schedule computes (matches
    repro.models.layers.chunked_attention exactly)."""
    nq = S // qc
    if window > 0:
        span = qc + ((window + kc - 1) // kc) * kc
        span = min(span, S)
        return nq * (span // kc)
    # causal: q chunk iq attends kv chunks 0..iq
    return nq * (nq + 1) // 2 if causal else nq * (S // kc)


def _attn_core(cfg: ArchConfig, B: int, S: int, qc: int, window: int) -> OpCounts:
    """Score+value matmuls of one attention layer (fwd), flash schedule."""
    H, hd = cfg.num_heads, cfg.resolved_head_dim
    Hk = cfg.num_kv_heads
    tiles = _attention_tiles(S, qc, qc, window)
    flops = 4.0 * B * H * hd * qc * qc * tiles  # qk^T + pv
    # HBM: q read once per q-chunk row; k/v re-streamed per q chunk (tiles)
    bytes_ = ACT_BYTES * B * (H * hd * S + 2 * Hk * hd * qc * tiles)
    return OpCounts(flops, bytes_)


def _linear(T: float, d_in: int, d_out: int) -> OpCounts:
    """One dense matmul over T tokens (fwd): weight re-read per use (FSDP)."""
    return OpCounts(2.0 * T * d_in * d_out, ACT_BYTES * T * (d_in + d_out) + P_BYTES * d_in * d_out)


def _layer_fwd(cfg: ArchConfig, B: int, S: int, mode: str) -> OpCounts:
    """Forward cost of ONE layer over (B, S) tokens (S=1 w/ cache for decode)."""
    d, f = cfg.d_model, cfg.d_ff
    H, Hk, hd = cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
    T = B * S
    c = OpCounts()
    fam = cfg.family

    if fam in ("ssm", "hybrid"):
        di, N, Hs, Ps = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_head_dim
        conv_dim = di + 2 * N
        c += _linear(T, d, 2 * di + 2 * N + Hs)  # in_proj
        c += OpCounts(2.0 * T * conv_dim * cfg.ssm_conv, ACT_BYTES * T * 2 * conv_dim)
        if mode == "decode":
            # recurrent update: h <- a h + dt B x ; y = C h  (2 x HPN each)
            c += OpCounts(6.0 * B * Hs * Ps * N, 2 * ACT_BYTES * B * Hs * Ps * N)
        else:
            L = min(cfg.ssm_chunk, S)
            nc = S // L
            intra = 2.0 * B * nc * L * L * (N + Hs * Ps)  # scores + y_intra
            states = 2.0 * B * nc * L * Hs * Ps * N * 2  # states + y_inter
            c += OpCounts(intra + states, ACT_BYTES * T * 3 * di)
        c += _linear(T, di, d)  # out_proj
        if fam == "hybrid":
            # shared attention+MLP block amortized: applied every k-th layer.
            # (Decode-time shared attention over the cache is added by
            # analytic_costs via _decode_attn, scaled by n_sites/L.)
            share = 1.0 / cfg.shared_attn_every
            blk = _linear(T, d, (H + 2 * Hk) * hd) + _linear(T, H * hd, d)
            blk += _linear(T, d, 2 * f) + _linear(T, f, d)
            if mode != "decode":
                blk += _attn_core(cfg, B, S, min(512, S), cfg.sliding_window)
            c += blk.scale(share)
        return c

    # attention families
    c += _linear(T, d, (H + 2 * Hk) * hd)  # fused qkv
    if mode == "decode":
        Sc = 0  # filled by caller via decode_cache_len
    else:
        c += _attn_core(cfg, B, S, min(512, S), cfg.sliding_window)
    c += _linear(T, H * hd, d)  # wo

    if fam == "moe":
        E, k, fe = cfg.num_experts, cfg.experts_per_token, cfg.resolved_moe_d_ff
        c += _linear(T, d, E)  # router
        eff_tokens = k * T if mode == "decode" else 1.25 * k * T  # capacity
        c += _linear(eff_tokens, d, 2 * fe) + _linear(eff_tokens, fe, d)
        if cfg.num_shared_experts:
            fs = fe * cfg.num_shared_experts
            c += _linear(T, d, 2 * fs) + _linear(T, fs, d)
    else:
        c += _linear(T, d, 2 * f) + _linear(T, f, d)
    return c


def _decode_attn(cfg: ArchConfig, B: int, cache_len: int) -> OpCounts:
    H, Hk, hd = cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
    flops = 4.0 * B * H * hd * cache_len
    bytes_ = 2 * ACT_BYTES * B * Hk * hd * cache_len  # read k+v cache
    return OpCounts(flops, bytes_)


def analytic_costs(cfg: ArchConfig, shape: ShapeConfig, *, chips: int = 256) -> dict:
    """Per-chip {flops, hbm_bytes} for the step this shape lowers."""
    B, S = shape.global_batch, shape.seq_len
    mode = shape.mode
    V, d, L = cfg.vocab_size, cfg.d_model, cfg.num_layers
    n_params = cfg.param_count()

    if mode == "train":
        per_layer = _layer_fwd(cfg, B, S, mode).scale(4.0)  # fwd+remat+bwd
        head = _linear(B * S, d, V).scale(3.0)  # logits fwd+bwd (no remat)
        total = per_layer.scale(L) + head
        if cfg.modality == "vision":
            total += _linear(B * cfg.frontend_tokens, 1024, d).scale(3.0)
        # optimizer: ~16 flops/param, m/v/p read+write f32
        total += OpCounts(16.0 * n_params, 10.0 * P_BYTES * n_params)
        # loss softmax traffic over logits
        total += OpCounts(0.0, 4 * 4.0 * B * S * V / 2)
    elif mode == "prefill":
        per_layer = _layer_fwd(cfg, B, S, mode)
        head = _linear(B, d, V)  # last position only
        total = per_layer.scale(L) + head
        if cfg.modality == "vision":
            total += _linear(B * cfg.frontend_tokens, 1024, d)
        # prefill emits the kv/state cache
        total += OpCounts(0.0, _cache_bytes(cfg, B, S))
    else:  # decode
        cache_len = min(S, cfg.sliding_window) if cfg.sliding_window else S
        per_layer = _layer_fwd(cfg, B, 1, mode)
        if cfg.family not in ("ssm",):
            if cfg.family == "hybrid":
                sc = min(S, cfg.sliding_window or S)
                n_sites = (L + cfg.shared_attn_every - 1) // cfg.shared_attn_every
                per_layer += _decode_attn(cfg, B, sc).scale(n_sites / L)
            else:
                per_layer += _decode_attn(cfg, B, cache_len)
        head = _linear(B, d, V)
        total = per_layer.scale(L) + head

    return {"flops": total.flops / chips, "hbm_bytes": total.hbm_bytes / chips}


def _cache_bytes(cfg: ArchConfig, B: int, S: int) -> float:
    L = cfg.num_layers
    if cfg.family == "ssm":
        return ACT_BYTES * L * B * cfg.ssm_heads * cfg.ssm_head_dim * cfg.ssm_state
    kv_len = min(S, cfg.sliding_window) if cfg.sliding_window else S
    kv = 2 * ACT_BYTES * B * kv_len * cfg.num_kv_heads * cfg.resolved_head_dim
    if cfg.family == "hybrid":
        n_sites = (L + cfg.shared_attn_every - 1) // cfg.shared_attn_every
        ssm = ACT_BYTES * L * B * cfg.ssm_heads * cfg.ssm_head_dim * cfg.ssm_state
        return ssm + n_sites * kv
    return L * kv
