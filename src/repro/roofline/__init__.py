from repro.roofline.constants import TPU_V5E
from repro.roofline.hlo import collective_bytes
from repro.roofline.report import RooflineResult, analyze_compiled
