"""Target-hardware constants for the roofline model (TPU v5e)."""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class Chip:
    name: str
    peak_flops_bf16: float  # FLOP/s
    hbm_bw: float  # B/s
    ici_link_bw: float  # B/s per link
    hbm_bytes: float


TPU_V5E = Chip(
    name="tpu-v5e",
    peak_flops_bf16=197e12,
    hbm_bw=819e9,
    ici_link_bw=50e9,
    hbm_bytes=16 * 1024**3,
)
