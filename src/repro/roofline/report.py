"""Three-term roofline analysis of a compiled (dry-run) step.

  compute    = HLO_FLOPs_per_chip / peak_FLOP/s
  memory     = HLO_bytes_per_chip / HBM_bw
  collective = link_bytes_per_chip / ICI_link_bw

``compiled.cost_analysis()`` runs on the post-partitioning per-device
module, so its flops/bytes are already per chip.  MODEL_FLOPS uses the
6·N·D (train) / 2·N·D (inference) convention with N = active params, D =
processed tokens; the ratio MODEL_FLOPS / (HLO_FLOPs × chips) exposes
remat/capacity/causal-masking overheads.
"""
from __future__ import annotations

import dataclasses
import json
from typing import Any

from repro.roofline.constants import TPU_V5E, Chip
from repro.roofline.hlo import collective_bytes, collective_link_bytes


@dataclasses.dataclass
class RooflineResult:
    arch: str
    shape: str
    mesh: str
    chips: int
    # raw per-chip measurements
    flops_per_chip: float
    hbm_bytes_per_chip: float
    coll_bytes_by_kind: dict[str, int]
    link_bytes_per_chip: float
    # memory analysis (per chip)
    arg_bytes: int
    output_bytes: int
    temp_bytes: int
    peak_bytes: int
    # derived terms (seconds)
    t_compute: float
    t_memory: float
    t_collective: float
    bottleneck: str
    # usefulness
    model_flops: float
    useful_ratio: float
    microbatches: int = 1
    variant: str = ""

    def to_json(self) -> str:
        return json.dumps(dataclasses.asdict(self), indent=2)

    @property
    def step_time(self) -> float:
        """Roofline step-time estimate: max of the three terms (perfect
        overlap assumption — the optimistic bound)."""
        return max(self.t_compute, self.t_memory, self.t_collective)

    def summary(self) -> str:
        return (
            f"{self.arch:28s} {self.shape:12s} {self.mesh:10s} "
            f"compute={self.t_compute * 1e3:9.3f}ms memory={self.t_memory * 1e3:9.3f}ms "
            f"collective={self.t_collective * 1e3:9.3f}ms -> {self.bottleneck:10s} "
            f"useful={self.useful_ratio:6.1%}"
        )


def _mem_field(mem, name: str) -> int:
    try:
        v = getattr(mem, name)()
    except TypeError:
        v = getattr(mem, name)
    except AttributeError:
        return 0
    return int(v)


def model_flops_estimate(cfg, shape) -> float:
    """6·N_active·tokens for train, 2·N_active·tokens for inference."""
    n = cfg.active_param_count()
    if shape.mode == "train":
        return 6.0 * n * shape.global_batch * shape.seq_len
    if shape.mode == "prefill":
        return 2.0 * n * shape.global_batch * shape.seq_len
    return 2.0 * n * shape.global_batch  # decode: one token per sequence


def analyze_compiled(
    compiled,
    *,
    arch: str,
    shape,
    cfg,
    mesh_name: str,
    chips: int,
    chip: Chip = TPU_V5E,
    microbatches: int = 1,
    variant: str = "",
) -> RooflineResult:
    cost = compiled.cost_analysis()
    if isinstance(cost, list):  # older jax returns [dict]
        cost = cost[0]
    flops = float(cost.get("flops", 0.0))
    hbm = float(cost.get("bytes accessed", 0.0))

    text = compiled.as_text()
    by_kind = collective_bytes(text)
    link_bytes = collective_link_bytes(by_kind)

    mem = compiled.memory_analysis()
    arg_b = _mem_field(mem, "argument_size_in_bytes")
    out_b = _mem_field(mem, "output_size_in_bytes")
    tmp_b = _mem_field(mem, "temp_size_in_bytes")
    peak = arg_b + tmp_b + out_b

    t_c = flops / chip.peak_flops_bf16
    t_m = hbm / chip.hbm_bw
    t_x = link_bytes / chip.ici_link_bw
    bottleneck = max(
        (("compute", t_c), ("memory", t_m), ("collective", t_x)), key=lambda kv: kv[1]
    )[0]

    mf = model_flops_estimate(cfg, shape)
    useful = mf / (flops * chips) if flops else 0.0

    return RooflineResult(
        arch=arch,
        shape=shape.name,
        mesh=mesh_name,
        chips=chips,
        flops_per_chip=flops,
        hbm_bytes_per_chip=hbm,
        coll_bytes_by_kind=by_kind,
        link_bytes_per_chip=link_bytes,
        arg_bytes=arg_b,
        output_bytes=out_b,
        temp_bytes=tmp_b,
        peak_bytes=peak,
        t_compute=t_c,
        t_memory=t_m,
        t_collective=t_x,
        bottleneck=bottleneck,
        model_flops=mf,
        useful_ratio=useful,
        microbatches=microbatches,
        variant=variant,
    )
