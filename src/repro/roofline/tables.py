"""Render the dry-run JSON results into the EXPERIMENTS.md tables."""
from __future__ import annotations

import json
import pathlib


def _fmt_bytes(b: float) -> str:
    for unit in ("B", "KB", "MB", "GB", "TB"):
        if abs(b) < 1024 or unit == "TB":
            return f"{b:.2f}{unit}"
        b /= 1024
    return f"{b:.2f}TB"


def _fmt_ms(s: float) -> str:
    return f"{s * 1e3:.3f}"


def load_results(json_dir: str) -> list[dict]:
    out = []
    for p in sorted(pathlib.Path(json_dir).glob("*.json")):
        out.append(json.loads(p.read_text()))
    return out


SHAPE_ORDER = {"train_4k": 0, "prefill_32k": 1, "decode_32k": 2, "long_500k": 3}


def roofline_table(results: list[dict]) -> str:
    rows = sorted(results, key=lambda r: (r["arch"], SHAPE_ORDER.get(r["shape"], 9)))
    lines = [
        "| arch | shape | mb | compute (ms) | memory (ms) | collective (ms) | bottleneck | "
        "MODEL_FLOPS | useful | HBM/chip peak |",
        "|---|---|---:|---:|---:|---:|---|---:|---:|---:|",
    ]
    for r in rows:
        lines.append(
            f"| {r['arch']}{r.get('variant', '')} | {r['shape']} | {r['microbatches']} "
            f"| {_fmt_ms(r['t_compute'])} | {_fmt_ms(r['t_memory'])} | {_fmt_ms(r['t_collective'])} "
            f"| **{r['bottleneck']}** | {r['model_flops']:.2e} | {r['useful_ratio']:.1%} "
            f"| {_fmt_bytes(r['peak_bytes'])} |"
        )
    return "\n".join(lines)


def dryrun_table(results: list[dict]) -> str:
    rows = sorted(results, key=lambda r: (r["arch"], SHAPE_ORDER.get(r["shape"], 9)))
    lines = [
        "| arch | shape | mesh | args/chip | temp/chip | flops/chip | link bytes/chip | collectives |",
        "|---|---|---|---:|---:|---:|---:|---|",
    ]
    for r in rows:
        kinds = ", ".join(
            f"{k}:{_fmt_bytes(v)}" for k, v in sorted(r["coll_bytes_by_kind"].items())
        )
        lines.append(
            f"| {r['arch']}{r.get('variant', '')} | {r['shape']} | {r['mesh']} "
            f"| {_fmt_bytes(r['arg_bytes'])} | {_fmt_bytes(r['temp_bytes'])} "
            f"| {r['flops_per_chip']:.2e} | {_fmt_bytes(r['link_bytes_per_chip'])} | {kinds} |"
        )
    return "\n".join(lines)


def main():
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--json-dir", default="experiments/dryrun")
    ap.add_argument("--kind", choices=["roofline", "dryrun"], default="roofline")
    args = ap.parse_args()
    results = load_results(args.json_dir)
    print(roofline_table(results) if args.kind == "roofline" else dryrun_table(results))


if __name__ == "__main__":
    main()
