from repro.utils.metrics import auc, mse
