"""Evaluation metrics used by the paper: MSE (continuous) and AUC (binary)."""
from __future__ import annotations

import numpy as np


def mse(y_true: np.ndarray, y_pred: np.ndarray) -> float:
    y_true = np.asarray(y_true, np.float64)
    y_pred = np.asarray(y_pred, np.float64)
    return float(np.mean((y_true - y_pred) ** 2))


def auc(y_true: np.ndarray, scores: np.ndarray) -> float:
    """Area under the ROC curve via the rank-sum (Mann-Whitney) statistic."""
    y_true = np.asarray(y_true).astype(bool)
    scores = np.asarray(scores, np.float64)
    n_pos = int(y_true.sum())
    n_neg = int((~y_true).sum())
    if n_pos == 0 or n_neg == 0:
        raise ValueError("AUC needs both classes present")
    order = np.argsort(scores, kind="mergesort")
    ranks = np.empty_like(order, dtype=np.float64)
    ranks[order] = np.arange(1, len(scores) + 1)
    # midranks for ties
    sorted_scores = scores[order]
    i = 0
    while i < len(sorted_scores):
        j = i
        while j + 1 < len(sorted_scores) and sorted_scores[j + 1] == sorted_scores[i]:
            j += 1
        if j > i:
            ranks[order[i : j + 1]] = 0.5 * (i + 1 + j + 1)
        i = j + 1
    rank_sum = ranks[y_true].sum()
    return float((rank_sum - n_pos * (n_pos + 1) / 2) / (n_pos * n_neg))
