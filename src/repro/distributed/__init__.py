from repro.distributed.sharding import (
    activation_spec,
    batch_shardings,
    cache_shardings,
    make_constrainer,
    param_shardings,
    sanitize_spec,
)
