"""Sharding rules: one table mapping every parameter / activation / cache
tensor to a PartitionSpec over the production mesh axes.

Conventions (DESIGN.md §4):
  * ``pod``, ``data`` — pure data parallelism.  Batch-like dims shard here.
    Weights are additionally FSDP-sharded over ``data`` (their d_model-like
    dim), all-gathered at use by GSPMD (or manually inside the MoE
    shard_map interior).  Gradients reduce over (pod, data) — the TPU-native
    form of the paper's key-value-free full-vector reduce.
  * ``model`` — tensor parallelism: attention heads / FFN hidden / vocab.

Every rule passes through :func:`sanitize_spec`, which drops any axis that
does not divide the corresponding dim (e.g. GQA kv-heads < |model|, batch=1
decode) — the config stays valid for every (arch × shape × mesh) without
per-case tables.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

from repro.models.moe import moe_param_specs

DATA_AXES = ("pod", "data")


def _dp(mesh: Mesh) -> tuple[str, ...]:
    return tuple(a for a in DATA_AXES if a in mesh.axis_names)


def _axis_size(mesh: Mesh, axis) -> int:
    if axis is None:
        return 1
    if isinstance(axis, (tuple, list)):
        n = 1
        for a in axis:
            n *= mesh.shape[a]
        return n
    return mesh.shape[axis]


def sanitize_spec(spec: P, shape: tuple[int, ...], mesh: Mesh) -> P:
    """Drop spec axes that don't divide the dim (replicate instead)."""
    entries = list(spec) + [None] * (len(shape) - len(spec))
    out = []
    for dim, axis in zip(shape, entries):
        if axis is not None and dim % _axis_size(mesh, axis) == 0 and dim > 0:
            out.append(axis)
        else:
            out.append(None)
    return P(*out)


# ------------------------------------------------------------- parameters


def _param_rule(path: tuple[str, ...], leaf, cfg) -> P:
    """PartitionSpec for one parameter leaf, by name + rank.

    Stacked layer params have a leading L dim (never sharded).
    """
    name = path[-1]
    stacked = "layers" in path
    nd = leaf.ndim - (1 if stacked else 0)  # rank without the L dim

    moe_specs = moe_param_specs(cfg) if cfg.num_experts else {}
    if name in moe_specs:
        body = moe_specs[name]
    elif name == "embed":
        body = P("model", "data")  # vocab x d_model
    elif name == "vision_proj":
        body = P("data", "model")
    elif name in ("wq", "wk", "wv", "w_gate", "w_up"):
        body = P("data", "model")  # d_model x (heads*hd | d_ff)
    elif name in ("wo", "w_down"):
        body = P("model", "data")
    elif name == "w_in":  # mamba in-proj: d_model x inner
        body = P("data", "model")
    elif name == "w_out":  # mamba out-proj: inner x d_model
        body = P("model", "data")
    elif name in ("bq",):
        body = P("model")
    elif nd <= 1:
        body = P(None)  # norms, biases, A_log, D, dt_bias, conv
    else:
        body = P(*([None] * nd))
    if stacked:
        body = P(None, *body)
    return body


def param_shardings(params_shape: Any, cfg, mesh: Mesh):
    """NamedSharding pytree matching a params (shape) pytree."""
    no_fsdp = getattr(cfg, "no_fsdp", False)

    def rule(path, leaf):
        names = tuple(p.key for p in path if hasattr(p, "key"))
        spec = _param_rule(names, leaf, cfg)
        if no_fsdp:  # §Perf lever: replicate weights over the data axis
            spec = P(*[None if a == "data" else a for a in spec])
        return NamedSharding(mesh, sanitize_spec(spec, leaf.shape, mesh))

    return jax.tree_util.tree_map_with_path(rule, params_shape)


# ------------------------------------------------------------ activations

_ACT_RULES = {
    # kind -> spec builder(dp)
    "act": lambda dp: P(dp, None, None),  # (B,S,D)
    "q_proj": lambda dp: P(dp, None, "model"),  # (B,S,H*hd)
    "kv_proj": lambda dp: P(dp, None, "model"),  # (B,S,Hk*hd)
    "ffn": lambda dp: P(dp, None, "model"),  # (B,S,F)
    "logits": lambda dp: P(dp, None, "model"),  # (B,S,V)
    "ssm_x": lambda dp: P(dp, None, "model", None),  # (B,S,H,P)
    # gathered (use-site) weight forms: replicated over data, TP over model.
    # Constraining the bf16 copy here makes GSPMD cast BEFORE the FSDP
    # all-gather (§Perf lever: bf16_weight_gather).
    "w_col": lambda dp: P(None, "model"),  # (D, F)-like
    "w_row": lambda dp: P("model", None),  # (F, D)-like
    "w_embed": lambda dp: P("model", None),  # (V, D)
}


def activation_spec(kind: str, mesh: Mesh) -> P:
    return _ACT_RULES[kind](_dp(mesh))


def make_constrainer(mesh: Mesh | None):
    """Returns constrain(x, kind) for lm_forward/lm_decode_step."""
    if mesh is None:
        return lambda x, kind: x

    def constrain(x, kind):
        spec = sanitize_spec(activation_spec(kind, mesh), x.shape, mesh)
        return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))

    return constrain


# -------------------------------------------------------- batches / caches


def batch_shardings(batch_shape: Any, mesh: Mesh):
    """Shard every batch leaf's leading (batch) dim over (pod, data)."""
    dp = _dp(mesh)

    def rule(leaf):
        spec = P(dp, *([None] * (leaf.ndim - 1)))
        return NamedSharding(mesh, sanitize_spec(spec, leaf.shape, mesh))

    return jax.tree.map(rule, batch_shape)


def cache_shardings(cache_shape: Any, cfg, mesh: Mesh):
    """KV caches: (L, B, S, Hk, hd) — batch over (pod,data), seq over model
    (distributed-softmax decode attention).  SSM states: (L, B, H, P, N) —
    batch over (pod,data), heads over model.  Falls back to replication per
    dim via sanitize."""
    dp = _dp(mesh)

    def rule(path, leaf):
        names = tuple(p.key for p in path if hasattr(p, "key"))
        if "ssm" in names and leaf.ndim == 5:  # (L,B,H,P,N) state
            spec = P(None, dp, "model", None, None)
        elif "ssm" in names:  # (L,B,K,conv) conv window
            spec = P(None, dp, None, None)
        elif leaf.ndim == 5:  # (L,B,S,Hk,hd) kv cache
            spec = P(None, dp, "model", None, None)
        else:
            spec = P(*([None] * leaf.ndim))
        return NamedSharding(mesh, sanitize_spec(spec, leaf.shape, mesh))

    return jax.tree_util.tree_map_with_path(rule, cache_shape)
