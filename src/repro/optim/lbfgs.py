"""Jittable L-BFGS with two-loop recursion and Armijo backtracking.

The paper optimizes the tight bound with "gradient descent and L-BFGS"
(§4.3.1).  This implementation works on arbitrary parameter pytrees via
ravel/unravel, keeps a fixed-size circular (s, y) history so the whole
optimization is a single lax.while_loop, and is reverse-mode safe.
"""
from __future__ import annotations

from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp
from jax.flatten_util import ravel_pytree


class LBFGSResult(NamedTuple):
    params: Any
    value: jax.Array
    grad_norm: jax.Array
    iterations: jax.Array
    converged: jax.Array


def _two_loop(s_hist, y_hist, rho_hist, head, count, grad):
    """Two-loop recursion over the circular history buffers."""
    m = s_hist.shape[0]

    def idx_at(i):
        # i = 0 is most recent
        return (head - 1 - i) % m

    def first_loop(i, carry):
        q, alphas = carry
        j = idx_at(i)
        valid = i < count
        alpha = jnp.where(valid, rho_hist[j] * jnp.dot(s_hist[j], q), 0.0)
        q = q - alpha * y_hist[j] * valid
        return q, alphas.at[i].set(alpha)

    q, alphas = jax.lax.fori_loop(0, m, first_loop, (grad, jnp.zeros((m,), grad.dtype)))
    # initial Hessian scaling gamma = s.y / y.y from most recent pair
    jr = idx_at(0)
    sy = jnp.dot(s_hist[jr], y_hist[jr])
    yy = jnp.dot(y_hist[jr], y_hist[jr])
    gamma = jnp.where(count > 0, sy / jnp.maximum(yy, 1e-30), 1.0)
    r = gamma * q

    def second_loop(i2, r):
        i = m - 1 - i2  # reverse order
        j = idx_at(i)
        valid = i < count
        beta = jnp.where(valid, rho_hist[j] * jnp.dot(y_hist[j], r), 0.0)
        return r + (alphas[i] - beta) * s_hist[j] * valid

    return jax.lax.fori_loop(0, m, second_loop, r)


def minimize(
    fun: Callable[[Any], jax.Array],
    x0: Any,
    *,
    history: int = 10,
    max_iters: int = 100,
    tol: float = 1e-6,
    max_linesearch: int = 20,
    armijo_c1: float = 1e-4,
    init_step: float = 1.0,
) -> LBFGSResult:
    """Minimize ``fun`` (scalar) over a pytree.  Jittable end to end."""
    flat0, unravel = ravel_pytree(x0)
    n = flat0.shape[0]
    dtype = flat0.dtype

    value_and_grad = jax.value_and_grad(lambda flat: fun(unravel(flat)))

    def line_search(flat, value, grad, direction):
        """Weak-Wolfe search: backtrack until Armijo holds, then expand while
        the curvature condition d.g_new >= c2 d.g still fails.  Guarantees the
        accepted pair has s^T y > 0 (so the L-BFGS history stays PD)."""
        c2 = 0.9
        dg = jnp.dot(direction, grad)
        # fall back to steepest descent if not a descent direction
        bad = dg >= 0
        direction = jnp.where(bad, -grad, direction)
        dg = jnp.where(bad, -jnp.dot(grad, grad), dg)

        def probe(step):
            nf, ng = value_and_grad(flat + step * direction)
            armijo = jnp.logical_and(
                jnp.isfinite(nf), nf <= value + armijo_c1 * step * dg
            )
            curv = jnp.dot(direction, ng) >= c2 * dg
            return nf, ng, armijo, curv

        class LS(NamedTuple):
            step: jax.Array
            best_step: jax.Array
            best_val: jax.Array
            best_grad: jax.Array
            have_best: jax.Array
            done: jax.Array
            tries: jax.Array

        def cond(s: LS):
            return jnp.logical_and(~s.done, s.tries < max_linesearch)

        def body(s: LS):
            nf, ng, armijo, curv = probe(s.step)
            take = armijo  # any Armijo point improves on what we have
            best_step = jnp.where(take, s.step, s.best_step)
            best_val = jnp.where(take, nf, s.best_val)
            best_grad = jnp.where(take, ng, s.best_grad)
            have_best = jnp.logical_or(s.have_best, take)
            done = jnp.logical_and(armijo, curv)
            # expand if Armijo ok but curvature slope still too negative;
            # once expansion breaks Armijo, settle for the best Armijo point.
            hit_ceiling = jnp.logical_and(~armijo, s.have_best)
            next_step = jnp.where(armijo, s.step * 2.0, s.step * 0.5)
            done = jnp.logical_or(done, hit_ceiling)
            return LS(next_step, best_step, best_val, best_grad, have_best, done, s.tries + 1)

        init = LS(
            jnp.asarray(init_step, dtype), jnp.asarray(0.0, dtype), value, grad,
            jnp.asarray(False), jnp.asarray(False), jnp.asarray(0),
        )
        out = jax.lax.while_loop(cond, body, init)
        keep = out.have_best
        new_flat = jnp.where(keep, flat + out.best_step * direction, flat)
        return new_flat, out.best_val, out.best_grad, keep

    class State(NamedTuple):
        flat: jax.Array
        value: jax.Array
        grad: jax.Array
        s_hist: jax.Array
        y_hist: jax.Array
        rho_hist: jax.Array
        head: jax.Array
        count: jax.Array
        it: jax.Array
        done: jax.Array

    v0, g0 = value_and_grad(flat0)
    init = State(
        flat0, v0, g0,
        jnp.zeros((history, n), dtype), jnp.zeros((history, n), dtype),
        jnp.zeros((history,), dtype), jnp.asarray(0), jnp.asarray(0),
        jnp.asarray(0), jnp.asarray(False),
    )

    def cond(st: State):
        return jnp.logical_and(~st.done, st.it < max_iters)

    def body(st: State):
        direction = -_two_loop(st.s_hist, st.y_hist, st.rho_hist, st.head, st.count, st.grad)
        new_flat, new_val, new_grad, ok = line_search(st.flat, st.value, st.grad, direction)
        s = new_flat - st.flat
        yv = new_grad - st.grad
        sy = jnp.dot(s, yv)
        accept = jnp.logical_and(ok, sy > 1e-10)
        head, count = st.head, st.count
        s_hist = jnp.where(accept, st.s_hist.at[head].set(s), st.s_hist)
        y_hist = jnp.where(accept, st.y_hist.at[head].set(yv), st.y_hist)
        rho_hist = jnp.where(
            accept, st.rho_hist.at[head].set(1.0 / jnp.maximum(sy, 1e-30)), st.rho_hist
        )
        head = jnp.where(accept, (head + 1) % history, head)
        count = jnp.where(accept, jnp.minimum(count + 1, history), count)
        gnorm = jnp.max(jnp.abs(new_grad))
        done = jnp.logical_or(gnorm < tol, ~ok)
        return State(
            new_flat, new_val, new_grad, s_hist, y_hist, rho_hist, head, count,
            st.it + 1, done,
        )

    final = jax.lax.while_loop(cond, body, init)
    return LBFGSResult(
        params=unravel(final.flat),
        value=final.value,
        grad_norm=jnp.max(jnp.abs(final.grad)),
        iterations=final.it,
        converged=final.done,
    )
