"""Minimal optax-style gradient-transformation optimizers (pure JAX).

The paper's outer loop is gradient descent or L-BFGS on the tight bound; we
additionally provide Adam (used by the model-zoo trainer).  All transforms
operate on arbitrary pytrees and are jit/scan-safe.
"""
from __future__ import annotations

from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp


class Optimizer(NamedTuple):
    init: Callable[[Any], Any]
    update: Callable[[Any, Any, Any], tuple[Any, Any]]  # (grads, state, params)


def apply_updates(params, updates):
    return jax.tree.map(lambda p, u: (p + u).astype(p.dtype), params, updates)


def chain(*transforms: Optimizer) -> Optimizer:
    def init(params):
        return tuple(t.init(params) for t in transforms)

    def update(grads, state, params=None):
        new_state = []
        for t, s in zip(transforms, state):
            grads, s = t.update(grads, s, params)
            new_state.append(s)
        return grads, tuple(new_state)

    return Optimizer(init, update)


def scale(factor: float) -> Optimizer:
    return Optimizer(
        init=lambda params: (),
        update=lambda g, s, p=None: (jax.tree.map(lambda x: factor * x, g), s),
    )


def scale_by_schedule(schedule: Callable[[jax.Array], jax.Array]) -> Optimizer:
    def init(params):
        return jnp.zeros((), jnp.int32)

    def update(grads, count, params=None):
        factor = schedule(count)
        return jax.tree.map(lambda x: factor * x, grads), count + 1

    return Optimizer(init, update)


def clip_by_global_norm(max_norm: float) -> Optimizer:
    def update(grads, state, params=None):
        leaves = jax.tree.leaves(grads)
        norm = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32))) for g in leaves))
        factor = jnp.minimum(1.0, max_norm / (norm + 1e-12))
        return jax.tree.map(lambda g: factor * g, grads), state

    return Optimizer(init=lambda p: (), update=update)


def sgd(learning_rate: float, momentum: float = 0.0) -> Optimizer:
    if momentum == 0.0:
        return scale(-learning_rate)

    def init(params):
        return jax.tree.map(jnp.zeros_like, params)

    def update(grads, mom, params=None):
        mom = jax.tree.map(lambda m, g: momentum * m + g, mom, grads)
        return jax.tree.map(lambda m: -learning_rate * m, mom), mom

    return Optimizer(init, update)


class AdamState(NamedTuple):
    count: jax.Array
    mu: Any
    nu: Any


def adam(
    learning_rate: float | Callable[[jax.Array], jax.Array],
    b1: float = 0.9,
    b2: float = 0.999,
    eps: float = 1e-8,
    weight_decay: float = 0.0,
) -> Optimizer:
    """Adam(W).  Moments are kept in f32 regardless of param dtype."""

    def init(params):
        f32 = lambda p: jnp.zeros(p.shape, jnp.float32)
        return AdamState(jnp.zeros((), jnp.int32), jax.tree.map(f32, params), jax.tree.map(f32, params))

    def update(grads, state, params=None):
        count = state.count + 1
        g32 = jax.tree.map(lambda g: g.astype(jnp.float32), grads)
        mu = jax.tree.map(lambda m, g: b1 * m + (1 - b1) * g, state.mu, g32)
        nu = jax.tree.map(lambda v, g: b2 * v + (1 - b2) * g * g, state.nu, g32)
        bc1 = 1 - b1**count.astype(jnp.float32)
        bc2 = 1 - b2**count.astype(jnp.float32)
        lr = learning_rate(count) if callable(learning_rate) else learning_rate

        def step(m, v, p):
            upd = -lr * (m / bc1) / (jnp.sqrt(v / bc2) + eps)
            if weight_decay and p is not None:
                upd = upd - lr * weight_decay * p.astype(jnp.float32)
            return upd

        if params is None:
            updates = jax.tree.map(lambda m, v: step(m, v, None), mu, nu)
        else:
            updates = jax.tree.map(step, mu, nu, params)
        return updates, AdamState(count, mu, nu)

    return Optimizer(init, update)
