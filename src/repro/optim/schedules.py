"""Learning-rate schedules (step-count -> multiplier or lr)."""
from __future__ import annotations

import jax.numpy as jnp


def constant(value: float):
    return lambda count: jnp.asarray(value, jnp.float32)


def linear_warmup_cosine(peak_lr: float, warmup_steps: int, total_steps: int, floor: float = 0.0):
    def schedule(count):
        count = count.astype(jnp.float32)
        warm = peak_lr * count / max(warmup_steps, 1)
        progress = jnp.clip(
            (count - warmup_steps) / max(total_steps - warmup_steps, 1), 0.0, 1.0
        )
        cos = floor + (peak_lr - floor) * 0.5 * (1.0 + jnp.cos(jnp.pi * progress))
        return jnp.where(count < warmup_steps, warm, cos)

    return schedule


def inverse_sqrt(peak_lr: float, warmup_steps: int):
    def schedule(count):
        count = jnp.maximum(count.astype(jnp.float32), 1.0)
        warm = peak_lr * count / max(warmup_steps, 1)
        decay = peak_lr * jnp.sqrt(warmup_steps / count)
        return jnp.where(count < warmup_steps, warm, decay)

    return schedule
