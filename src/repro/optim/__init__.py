from repro.optim.lbfgs import LBFGSResult, minimize
from repro.optim.optimizers import (
    Optimizer, adam, apply_updates, chain, clip_by_global_norm, scale,
    scale_by_schedule, sgd,
)
from repro.optim import schedules

__all__ = [
    "LBFGSResult", "Optimizer", "adam", "apply_updates", "chain",
    "clip_by_global_norm", "minimize", "scale", "scale_by_schedule",
    "schedules", "sgd",
]
