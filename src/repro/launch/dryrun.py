import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (architecture x input shape) on
the production mesh WITHOUT hardware, and extract the roofline terms.

The two lines above MUST run before any other import (jax locks the device
count at first init).  This module is the ONLY place that forces 512 host
devices — smoke tests and benchmarks see the real single CPU device.

Two passes per (arch x shape):

  scan pass   — the production program (lax.scan over layers, grad
    accumulation): full depth, both meshes.  Proves lowering/compiling
    succeeds and yields memory_analysis (the "fits" proof).  NOT used for
    flops/collective accounting: XLA's HloCostAnalysis counts a while body
    ONCE regardless of trip count.

  probe pass  — two SHALLOW LAYER-UNROLLED compiles (depths 2 and 4;
    hybrid archs use (k, 2k) so the shared-block cadence stays uniform),
    used for COLLECTIVE-byte extraction only.  Unrolled layers are
    structurally identical, so collective bytes are exactly linear in
    depth: bytes(L) = base + slope*L.  FLOPs/HBM bytes come from the
    analytic op model instead (roofline/analytic.py), calibrated against
    fully-unrolled HLO (tests/test_roofline.py + full 28/52-layer unrolls
    of qwen3-0.6b / granite-20b; see EXPERIMENTS.md §Dry-run methodology).

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-0.6b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all              # 40 pairs, single-pod
  PYTHONPATH=src python -m repro.launch.dryrun --all --multi-pod  # 512-chip pass
"""
import argparse
import dataclasses as dc
import json
import pathlib
import time
import traceback

import jax
import jax.numpy as jnp

from repro.configs import SHAPES, get_arch, list_archs
from repro.launch.mesh import make_production_mesh
from repro.launch.steps import (
    abstract_params,
    input_specs,
    make_prefill_step,
    make_serve_step,
    make_train_step,
    resolve_arch_for_shape,
    step_shardings,
)
from repro.optim import adam
from repro.roofline import TPU_V5E, analyze_compiled, collective_bytes
from repro.roofline.analytic import analytic_costs
from repro.roofline.hlo import collective_link_bytes
from repro.roofline.report import RooflineResult, model_flops_estimate


def default_microbatches(cfg, shape, mesh) -> int:
    """Grad-accumulation factor: keep per-device microbatch activations
    around <=128MB per layer boundary (tokens/dev/microbatch * d_model * 2B),
    while keeping batch/microbatch divisible by the data shards."""
    if shape.mode != "train":
        return 1
    dp = 1
    for a in ("pod", "data"):
        if a in mesh.axis_names:
            dp *= mesh.shape[a]
    tokens_per_dev = shape.global_batch * shape.seq_len // dp
    budget = 128 * 1024**2
    m = 1
    while (
        tokens_per_dev // m * cfg.d_model * 2 > budget
        and m * 2 <= shape.global_batch
        and (shape.global_batch // (m * 2)) % dp == 0
    ):
        m *= 2
    return m


def _compile_step(cfg, shape, mesh, microbatches: int):
    """jit + lower + compile the step selected by shape.mode for cfg."""
    params = abstract_params(cfg)
    shardings = step_shardings(cfg, shape, mesh)
    specs = input_specs(cfg, shape)
    with jax.set_mesh(mesh):
        if shape.mode == "train":
            _, train_step = make_train_step(cfg, mesh, microbatches=microbatches)
            opt_state = jax.eval_shape(adam(1e-4).init, params)
            fn = jax.jit(
                train_step,
                in_shardings=shardings,
                out_shardings=(shardings[0], shardings[1], None),
                donate_argnums=(0, 1),
            )
            lowered = fn.lower(params, opt_state, specs["batch"])
        elif shape.mode == "prefill":
            prefill_step = make_prefill_step(cfg, mesh)
            fn = jax.jit(prefill_step, in_shardings=shardings)
            lowered = fn.lower(params, specs["batch"])
        else:
            serve_step = make_serve_step(cfg, mesh)
            fn = jax.jit(
                serve_step,
                in_shardings=shardings,
                out_shardings=(None, shardings[1]),
                donate_argnums=(1,),
            )
            lowered = fn.lower(params, specs["cache"], specs["tokens"], specs["pos"])
        return lowered, lowered.compile()


def _extract_costs(compiled) -> dict:
    cost = compiled.cost_analysis()
    if isinstance(cost, list):
        cost = cost[0]
    by_kind = collective_bytes(compiled.as_text())
    return {
        "flops": float(cost.get("flops", 0.0)),
        "hbm_bytes": float(cost.get("bytes accessed", 0.0)),
        "link_bytes": collective_link_bytes(by_kind),
        "by_kind": by_kind,
    }


def probe_depths(cfg) -> tuple[int, int]:
    if cfg.family == "hybrid" and cfg.shared_attn_every:
        k = cfg.shared_attn_every
        return (k, 2 * k)
    return (2, 4)


def probe_collectives(cfg, shape, mesh, microbatches: int, *, verbose=True) -> dict:
    """Collective bytes from two shallow LAYER-UNROLLED compiles, linearly
    extrapolated to full depth (exact: unrolled layers are identical
    subgraphs, and no collective lives inside the attention/SSD chunk loops).

    The probes compile with microbatches=1; per-microbatch weight
    all-gathers (FSDP + the MoE shard_map interior) repeat per microbatch in
    the real program, so the all-gather bytes are scaled by M.  Token-sized
    collectives (psums/reduce-scatters over activations and gradients) are
    batch-total and M-invariant."""
    d1, d2 = probe_depths(cfg)
    cs = []
    for L in (d1, d2):
        cfgL = dc.replace(cfg, num_layers=L, scan_unroll=True)
        t0 = time.time()
        _, compiled = _compile_step(cfgL, shape, mesh, microbatches=1)
        cs.append(_extract_costs(compiled))
        if verbose:
            print(f"   probe L={L}: {time.time() - t0:.0f}s "
                  f"link_bytes={cs[-1]['link_bytes']:.3e}")
    kinds = set(cs[0]["by_kind"]) | set(cs[1]["by_kind"])
    by_kind = {}
    for k in kinds:
        a, b = cs[0]["by_kind"].get(k, 0), cs[1]["by_kind"].get(k, 0)
        v = a + (b - a) / (d2 - d1) * (cfg.num_layers - d1)
        if k == "all-gather" and microbatches > 1:
            v *= microbatches
        by_kind[k] = int(v)
    return {
        "by_kind": by_kind,
        "link_bytes": collective_link_bytes(by_kind),
        "probe_depths": [d1, d2],
    }


def _mem_field(mem, name: str) -> int:
    try:
        return int(getattr(mem, name))
    except (AttributeError, TypeError):
        try:
            return int(getattr(mem, name)())
        except Exception:  # noqa: BLE001
            return 0


def run_pair(arch: str, shape_name: str, *, multi_pod=False, microbatches=None,
             with_probe=True, verbose=True, overrides=None) -> RooflineResult:
    """Full dry-run of one (arch x shape x mesh): scan compile (+memory) and,
    optionally, the probe pass for roofline accounting.  ``overrides`` is a
    dict of ArchConfig field replacements (§Perf levers)."""
    mesh = make_production_mesh(multi_pod=multi_pod)
    mesh_name = "2x16x16" if multi_pod else "16x16"
    chips = 512 if multi_pod else 256
    shape = SHAPES[shape_name]
    cfg, variant = resolve_arch_for_shape(get_arch(arch), shape)
    if overrides:
        cfg = dc.replace(cfg, **overrides)
        variant = variant + "+" + ",".join(overrides)
    mb = microbatches or default_microbatches(cfg, shape, mesh)

    t0 = time.time()
    lowered, compiled = _compile_step(cfg, shape, mesh, microbatches=mb)
    t_scan = time.time() - t0
    mem = compiled.memory_analysis()
    if verbose:
        print(f"== {arch} x {shape_name} ({mesh_name}, mb={mb}{variant}) [compile {t_scan:.0f}s]")
        print(f"   memory_analysis: {mem}")
        ca = compiled.cost_analysis()
        print(f"   cost_analysis(scan): flops={ca.get('flops', 0):.3e} "
              f"bytes={ca.get('bytes accessed', 0):.3e}")

    # flops / HBM bytes: analytic op model (validated vs unrolled HLO —
    # tests/test_roofline.py); collectives: HLO probe extrapolation.
    costs = analytic_costs(cfg, shape, chips=chips)
    if with_probe:
        costs.update(probe_collectives(cfg, shape, mesh, mb, verbose=verbose))
    else:
        hlo = _extract_costs(compiled)
        costs["by_kind"] = hlo["by_kind"]
        costs["link_bytes"] = hlo["link_bytes"]

    chip = TPU_V5E
    t_c = costs["flops"] / chip.peak_flops_bf16
    t_m = costs["hbm_bytes"] / chip.hbm_bw
    t_x = costs["link_bytes"] / chip.ici_link_bw
    bottleneck = max((("compute", t_c), ("memory", t_m), ("collective", t_x)),
                     key=lambda kv: kv[1])[0]
    mf = model_flops_estimate(cfg, shape)
    res = RooflineResult(
        arch=arch,
        shape=shape_name,
        mesh=mesh_name,
        chips=chips,
        flops_per_chip=costs["flops"],
        hbm_bytes_per_chip=costs["hbm_bytes"],
        coll_bytes_by_kind=costs["by_kind"],
        link_bytes_per_chip=costs["link_bytes"],
        arg_bytes=_mem_field(mem, "argument_size_in_bytes"),
        output_bytes=_mem_field(mem, "output_size_in_bytes"),
        temp_bytes=_mem_field(mem, "temp_size_in_bytes"),
        peak_bytes=_mem_field(mem, "argument_size_in_bytes")
        + _mem_field(mem, "temp_size_in_bytes")
        + _mem_field(mem, "output_size_in_bytes")
        - _mem_field(mem, "alias_size_in_bytes"),
        t_compute=t_c,
        t_memory=t_m,
        t_collective=t_x,
        bottleneck=bottleneck,
        model_flops=mf,
        useful_ratio=mf / (costs["flops"] * chips) if costs["flops"] else 0.0,
        microbatches=mb,
        variant=variant,
    )
    if verbose:
        print("   " + res.summary())
    return res


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None, help="architecture id (default: all)")
    ap.add_argument("--shape", default=None, help="input shape (default: all)")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--no-probe", action="store_true",
                    help="skip the unrolled probe pass (pass/fail + memory only)")
    ap.add_argument("--microbatches", type=int, default=None)
    ap.add_argument("--json-dir", default="experiments/dryrun")
    ap.add_argument("--bf16-gather", action="store_true", help="§Perf: bf16 weight all-gathers")
    ap.add_argument("--no-fsdp", action="store_true", help="§Perf: replicate weights over data")
    ap.add_argument("--bf16-params", action="store_true", help="§Perf: bf16 stored weights")
    ap.add_argument("--bf16-cotangents", action="store_true", help="§Perf: bf16 bwd dx")
    ap.add_argument("--remat-save", action="store_true", help="§Perf: save sublayer outputs (no remat re-psum)")
    args = ap.parse_args()
    overrides = {}
    if args.bf16_gather:
        overrides["bf16_weight_gather"] = True
    if args.no_fsdp:
        overrides["no_fsdp"] = True
    if args.bf16_params:
        overrides["bf16_params"] = True
    if args.bf16_cotangents:
        overrides["bf16_cotangents"] = True
    if args.remat_save:
        overrides["remat_save_outputs"] = True

    archs = [args.arch] if args.arch else list_archs()
    shapes = [args.shape] if args.shape else list(SHAPES)
    outdir = pathlib.Path(args.json_dir)
    outdir.mkdir(parents=True, exist_ok=True)
    mesh_tag = "2x16x16" if args.multi_pod else "16x16"

    failures = []
    for arch in archs:
        for shape_name in shapes:
            try:
                res = run_pair(
                    arch, shape_name,
                    multi_pod=args.multi_pod,
                    microbatches=args.microbatches,
                    with_probe=not args.no_probe,
                    overrides=overrides or None,
                )
            except Exception as e:  # noqa: BLE001 — report and continue the sweep
                failures.append((arch, shape_name, repr(e)))
                print(f"FAIL {arch} {shape_name}: {e}")
                traceback.print_exc()
                continue
            (outdir / f"{arch}_{shape_name}_{mesh_tag}.json").write_text(res.to_json())

    total = len(archs) * len(shapes)
    print(f"\n{total - len(failures)}/{total} ok")
    for a, s, e in failures:
        print(f"  FAIL {a} {s}: {e}")
    raise SystemExit(1 if failures else 0)


if __name__ == "__main__":
    main()
