"""Step factories + abstract input specs for every (arch × input-shape).

``input_specs(cfg, shape)`` builds ShapeDtypeStruct stand-ins (weak-type
correct, shardable, ZERO device allocation) for everything a step consumes —
the same pattern the dry-run lowers against.  ``make_train_step`` /
``make_prefill_step`` / ``make_serve_step`` return pure jittable functions.

Shape semantics (configs.base.SHAPES):
  train_4k     -> train_step(params, opt_state, batch)
  prefill_32k  -> prefill_step(params, batch) -> (last logits, decode cache)
  decode_32k / long_500k -> serve_step(params, cache, tokens, pos) — ONE new
    token against a context_len cache.  long_500k picks the sliding-window
    VARIANT for pure full-attention archs (cfg.with_long_context_window),
    and is native for ssm/hybrid/SWA archs.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs import ArchConfig, ShapeConfig
from repro.distributed import batch_shardings, cache_shardings, make_constrainer, param_shardings
from repro.models import init_decode_cache, init_lm_params, lm_decode_step, lm_loss
from repro.models.lm import D_VISION, lm_prefill
from repro.optim import adam, apply_updates

LONG_CONTEXT_SEQ = 131072  # >= this, pure full attention is not allowed


def resolve_arch_for_shape(cfg: ArchConfig, shape: ShapeConfig) -> tuple[ArchConfig, str]:
    """Apply the long-context sliding-window variant when required.

    Returns (possibly modified cfg, variant tag '' | '+swa')."""
    if shape.seq_len >= LONG_CONTEXT_SEQ and not cfg.supports_seq_len(shape.seq_len):
        return cfg.with_long_context_window(), "+swa"
    return cfg, ""


# ------------------------------------------------------------ input specs


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def train_batch_specs(cfg: ArchConfig, shape: ShapeConfig) -> dict:
    B, S = shape.global_batch, shape.seq_len
    specs = {
        "labels": _sds((B, S), jnp.int32),
        "mask": _sds((B, S), jnp.float32),
    }
    if cfg.modality == "vision":
        specs["tokens"] = _sds((B, S - cfg.frontend_tokens), jnp.int32)
        specs["patch_embeds"] = _sds((B, cfg.frontend_tokens, D_VISION), jnp.bfloat16)
    else:
        specs["tokens"] = _sds((B, S), jnp.int32)
    return specs


def prefill_batch_specs(cfg: ArchConfig, shape: ShapeConfig) -> dict:
    specs = train_batch_specs(cfg, shape)
    specs.pop("labels")
    specs.pop("mask")
    return specs


def decode_cache_specs(cfg: ArchConfig, shape: ShapeConfig) -> Any:
    return jax.eval_shape(
        lambda: init_decode_cache(cfg, shape.global_batch, context_len=shape.seq_len)
    )


def input_specs(cfg: ArchConfig, shape: ShapeConfig) -> dict:
    """All abstract inputs for the step this shape lowers (excluding params
    and optimizer state, which come from ``abstract_params``)."""
    cfg, _ = resolve_arch_for_shape(cfg, shape)
    if shape.mode == "train":
        return {"batch": train_batch_specs(cfg, shape)}
    if shape.mode == "prefill":
        return {"batch": prefill_batch_specs(cfg, shape)}
    return {
        "cache": decode_cache_specs(cfg, shape),
        "tokens": _sds((shape.global_batch, 1), jnp.int32),
        "pos": _sds((), jnp.int32),
    }


def abstract_params(cfg: ArchConfig, dtype=None):
    if dtype is None:
        dtype = jnp.bfloat16 if cfg.bf16_params else jnp.float32
    return jax.eval_shape(partial(init_lm_params, cfg=cfg, dtype=dtype), jax.random.PRNGKey(0))


# -------------------------------------------------------------- factories


def make_train_step(
    cfg: ArchConfig,
    mesh=None,
    *,
    microbatches: int = 1,
    learning_rate: float = 3e-4,
    weight_decay: float = 0.0,
):
    """Returns (optimizer, train_step(params, opt_state, batch))."""
    constrain = make_constrainer(mesh)
    opt = adam(learning_rate, weight_decay=weight_decay)

    def loss_fn(params, mb):
        return lm_loss(params, mb, cfg, mesh=mesh, constrain=constrain)

    def train_step(params, opt_state, batch):
        if microbatches > 1:
            # (B, ...) -> (M, B/M, ...) keeping the SECOND dim as the sharded
            # batch dim (reshape groups M minor so device-local rows stay
            # device-local; the swap is sharding-metadata only).
            def split(a):
                B = a.shape[0]
                return a.reshape(B // microbatches, microbatches, *a.shape[1:]).swapaxes(0, 1)

            mbs = jax.tree.map(split, batch)

            def body(acc, mb):
                gsum, lsum = acc
                (l, _), g = jax.value_and_grad(loss_fn, has_aux=True)(params, mb)
                return (jax.tree.map(jnp.add, gsum, g), lsum + l), None

            zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (gsum, lsum), _ = lax.scan(body, (zeros, jnp.float32(0.0)), mbs)
            grads = jax.tree.map(lambda g: g / microbatches, gsum)
            loss = lsum / microbatches
        else:
            (loss, _), grads = jax.value_and_grad(loss_fn, has_aux=True)(params, batch)
        updates, opt_state = opt.update(grads, opt_state, params)
        params = apply_updates(params, updates)
        return params, opt_state, {"loss": loss}

    return opt, train_step


def make_prefill_step(cfg: ArchConfig, mesh=None):
    constrain = make_constrainer(mesh)

    def prefill_step(params, batch):
        return lm_prefill(params, batch, cfg, mesh=mesh, constrain=constrain)

    return prefill_step


def make_serve_step(cfg: ArchConfig, mesh=None):
    constrain = make_constrainer(mesh)

    def serve_step(params, cache, tokens, pos):
        return lm_decode_step(params, cache, tokens, pos, cfg, mesh=mesh, constrain=constrain)

    return serve_step


# --------------------------------------------------------- spec shardings


def step_shardings(cfg: ArchConfig, shape: ShapeConfig, mesh):
    """(in_shardings, args) for jit+lower of the step this shape selects."""
    cfg, _ = resolve_arch_for_shape(cfg, shape)
    params = abstract_params(cfg)
    p_sh = param_shardings(params, cfg, mesh)
    from jax.sharding import NamedSharding, PartitionSpec as P

    repl = NamedSharding(mesh, P())
    if shape.mode == "train":
        batch = train_batch_specs(cfg, shape)
        b_sh = batch_shardings(batch, mesh)
        opt_state = jax.eval_shape(adam(1e-4).init, params)
        o_sh = type(opt_state)(repl, p_sh, p_sh)
        return (p_sh, o_sh, b_sh)
    if shape.mode == "prefill":
        batch = prefill_batch_specs(cfg, shape)
        return (p_sh, batch_shardings(batch, mesh))
    cache = decode_cache_specs(cfg, shape)
    c_sh = cache_shardings(cache, cfg, mesh)
    tok_sh = batch_shardings(_sds((shape.global_batch, 1), jnp.int32), mesh)
    return (p_sh, c_sh, tok_sh, repl)
