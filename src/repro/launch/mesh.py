"""Production mesh construction.

Kept as FUNCTIONS (never module-level constants) so importing this module
never touches jax device state — the dry-run must set XLA_FLAGS before the
first jax device query, and smoke tests must keep seeing one CPU device.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """TPU v5e production mesh: one pod = (data=16, model=16) = 256 chips;
    two pods add a leading pure-DP 'pod' axis = 512 chips."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(model: int | None = None):
    """Small debug mesh over whatever devices exist (CPU forced-host runs)."""
    n = len(jax.devices())
    model = model or (2 if n % 2 == 0 and n > 1 else 1)
    return jax.make_mesh((n // model, model), ("data", "model"))
