"""Serving driver: batched prefill + token-by-token decode.

Example (CPU, reduced config):
  PYTHONPATH=src python -m repro.launch.serve --arch qwen3-0.6b --reduced \
      --batch 4 --prompt-len 64 --gen 32
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_arch, get_reduced
from repro.launch.mesh import make_host_mesh, make_production_mesh
from repro.launch.steps import make_prefill_step, make_serve_step
from repro.models import init_lm_params
from repro.models.lm import lm_prefill


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--temperature", type=float, default=0.0, help="0 = greedy")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--mesh", choices=["none", "host"], default="none")
    args = ap.parse_args(argv)

    cfg = get_reduced(args.arch) if args.reduced else get_arch(args.arch)
    mesh = make_host_mesh() if args.mesh == "host" else None
    key = jax.random.PRNGKey(args.seed)
    params = init_lm_params(key, cfg)

    B, S = args.batch, args.prompt_len
    rng = np.random.default_rng(args.seed)
    prompt = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)), jnp.int32)}
    if cfg.modality == "vision":
        prompt["patch_embeds"] = jnp.asarray(
            rng.normal(size=(B, cfg.frontend_tokens, 1024)) * 0.02, cfg.activation_dtype
        )
        S = S + cfg.frontend_tokens

    ctx = S + args.gen
    t0 = time.time()
    logits, cache = jax.jit(
        lambda p, b: lm_prefill(p, b, cfg, mesh=mesh, context_len=ctx)
    )(params, prompt)
    print(f"prefill {B}x{S}: {time.time() - t0:.2f}s")

    serve_step = jax.jit(make_serve_step(cfg, mesh), donate_argnums=(1,))
    tok = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
    out = [tok]
    t0 = time.time()
    for i in range(args.gen):
        logits, cache = serve_step(params, cache, tok, jnp.int32(S + i))
        if args.temperature > 0:
            key, sub = jax.random.split(key)
            tok = jax.random.categorical(sub, logits[:, -1] / args.temperature)[:, None]
            tok = tok.astype(jnp.int32)
        else:
            tok = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
        out.append(tok)
    dt = time.time() - t0
    gen = np.asarray(jnp.concatenate(out, axis=1))
    print(f"decode {args.gen} steps: {dt:.2f}s ({B * args.gen / dt:.1f} tok/s)")
    print("sample[0]:", gen[0].tolist())
    return gen


if __name__ == "__main__":
    main()
