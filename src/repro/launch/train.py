"""LM training driver.

Runs any assigned architecture (``--arch``) at any scale:
  * real training on the available devices (CPU smoke / TPU slice) with a
    host mesh, synthetic-token data pipeline, checkpointing;
  * ``--production-mesh`` switches to the 16x16 / 2x16x16 meshes (requires a
    matching real topology or the forced-host dry-run environment).

Example (CPU, reduced config):
  PYTHONPATH=src python -m repro.launch.train --arch qwen3-0.6b --reduced \
      --steps 20 --batch 8 --seq 128
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import save
from repro.configs import get_arch, get_reduced
from repro.data.loader import token_batches
from repro.distributed import batch_shardings, param_shardings
from repro.launch.mesh import make_host_mesh, make_production_mesh
from repro.launch.steps import make_train_step
from repro.models import init_lm_params
from repro.optim import adam


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true", help="use the smoke-test-scale variant")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--mesh", choices=["none", "host", "production", "production-multipod"], default="none")
    ap.add_argument("--checkpoint", default=None, help="path to save the final checkpoint")
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args(argv)

    cfg = get_reduced(args.arch) if args.reduced else get_arch(args.arch)
    mesh = None
    if args.mesh == "host":
        mesh = make_host_mesh()
    elif args.mesh.startswith("production"):
        mesh = make_production_mesh(multi_pod=args.mesh.endswith("multipod"))

    key = jax.random.PRNGKey(args.seed)
    params = init_lm_params(key, cfg)
    opt, train_step = make_train_step(
        cfg, mesh, microbatches=args.microbatches, learning_rate=args.lr
    )
    opt_state = opt.init(params)

    if mesh is not None:
        p_sh = param_shardings(jax.eval_shape(lambda: params), cfg, mesh)
        params = jax.device_put(params, p_sh)
        step_fn = jax.jit(train_step, donate_argnums=(0, 1))
    else:
        step_fn = jax.jit(train_step, donate_argnums=(0, 1))

    losses = []
    t0 = time.time()
    for step, batch in enumerate(token_batches(cfg, args.batch, args.seq, seed=args.seed)):
        if step >= args.steps:
            break
        params, opt_state, metrics = step_fn(params, opt_state, batch)
        loss = float(metrics["loss"])
        losses.append(loss)
        if step % args.log_every == 0 or step == args.steps - 1:
            dt = time.time() - t0
            tok_s = (step + 1) * args.batch * args.seq / dt
            print(f"step {step:5d} loss {loss:8.4f}  ({tok_s:,.0f} tok/s)")

    print(f"final loss {losses[-1]:.4f} (start {losses[0]:.4f})")
    if args.checkpoint:
        save(args.checkpoint, {"params": params, "step": args.steps})
        print(f"checkpoint -> {args.checkpoint}")
    return losses


if __name__ == "__main__":
    main()
