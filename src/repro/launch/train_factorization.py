"""Paper driver: distributed flexible nonlinear tensor factorization.

Trains the DFNTF model (repro.core) on any of the paper's dataset
footprints with balanced zero/nonzero sampling, exactly the §6 protocol.

Example:
  PYTHONPATH=src python -m repro.launch.train_factorization --dataset alog \
      --optimizer lbfgs --max-nnz 2000
"""
from __future__ import annotations

import argparse
import time

import numpy as np

from repro.core.model import DFNTF, FitConfig
from repro.data import balanced_train_test, kfold_split, make_sparse_tensor
from repro.utils.metrics import auc, mse


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--dataset", default="alog")
    ap.add_argument("--rank", type=int, default=3)
    ap.add_argument("--inducing", type=int, default=100)
    ap.add_argument("--optimizer", choices=["adam", "gd", "lbfgs"], default="adam")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--lr", type=float, default=1e-2)
    ap.add_argument("--max-nnz", type=int, default=4000)
    ap.add_argument("--dim-scale", type=float, default=1.0)
    ap.add_argument("--kernel", default="ard")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    tensor, _ = make_sparse_tensor(args.dataset, seed=args.seed, max_nnz=args.max_nnz, dim_scale=args.dim_scale)
    binary = bool(np.all(tensor.vals == 1.0))
    rng = np.random.default_rng(args.seed)
    train_rows, test_rows = kfold_split(rng, tensor, folds=5)[0]
    train, test = balanced_train_test(rng, tensor, train_rows, test_rows, binary=binary)
    print(f"{args.dataset}: dims={tensor.dims} nnz={tensor.nnz} "
          f"{'binary' if binary else 'continuous'}; train={len(train)} test={len(test)}")

    cfg = FitConfig(
        task="binary" if binary else "continuous",
        kernel_kind=args.kernel,
        rank=args.rank,
        num_inducing=args.inducing,
        optimizer=args.optimizer,
        learning_rate=args.lr,
        steps=args.steps,
        seed=args.seed,
    )
    model = DFNTF(tensor.dims, cfg)
    t0 = time.time()
    model.fit(train, verbose=True)
    print(f"fit: {time.time() - t0:.1f}s  final ELBO={model.elbo():.2f}")

    if binary:
        p = model.predict_proba(test.idx)
        print(f"test AUC = {auc(test.y, p):.4f}")
    else:
        yhat = model.predict(test.idx)
        print(f"test MSE = {mse(test.y, yhat):.4f}")


if __name__ == "__main__":
    main()
