"""End-to-end LM training driver: train a ~100M-parameter qwen3-family model
for a few hundred steps on the synthetic token pipeline, then decode from it.

~100M params: 12 layers x d_model 512 + a 32k vocab (see below).  Runs on
CPU in tens of minutes; on a real TPU slice pass --mesh host.

  PYTHONPATH=src python examples/train_lm.py --steps 300
"""
import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp

from repro.configs import get_arch
from repro.data.loader import token_batches
from repro.launch.steps import make_train_step
from repro.models import init_decode_cache, init_lm_params, lm_decode_step
from repro.models.lm import lm_prefill

ap = argparse.ArgumentParser()
ap.add_argument("--steps", type=int, default=300)
ap.add_argument("--batch", type=int, default=8)
ap.add_argument("--seq", type=int, default=128)
ap.add_argument("--lr", type=float, default=1e-3)
args = ap.parse_args()

# ~100M-parameter member of the qwen3 family
cfg = dataclasses.replace(
    get_arch("qwen3-0.6b"),
    num_layers=12, d_model=512, num_heads=8, num_kv_heads=4, d_ff=1536,
    vocab_size=32768, dtype="float32",
)
params = init_lm_params(jax.random.PRNGKey(0), cfg)
n = sum(p.size for p in jax.tree.leaves(params))
print(f"model: {n / 1e6:.1f}M params ({cfg.num_layers}L d{cfg.d_model})")

opt, train_step = make_train_step(cfg, None, learning_rate=args.lr)
opt_state = opt.init(params)
step_fn = jax.jit(train_step, donate_argnums=(0, 1))

t0 = time.time()
losses = []
for step, batch in enumerate(token_batches(cfg, args.batch, args.seq, seed=0)):
    if step >= args.steps:
        break
    params, opt_state, m = step_fn(params, opt_state, batch)
    losses.append(float(m["loss"]))
    if step % 25 == 0 or step == args.steps - 1:
        tok_s = (step + 1) * args.batch * args.seq / (time.time() - t0)
        print(f"step {step:4d}  loss {losses[-1]:7.4f}  ({tok_s:,.0f} tok/s)")

print(f"\nloss: {losses[0]:.3f} -> {losses[-1]:.3f} "
      f"({'learning' if losses[-1] < losses[0] - 0.3 else 'check hyperparams'})")

# ---- decode a few tokens greedily from a prompt
prompt = next(token_batches(cfg, 2, 32, seed=1))["tokens"]
logits, cache = jax.jit(lambda p, b: lm_prefill(p, b, cfg, context_len=64))(
    params, {"tokens": prompt}
)
tok = jnp.argmax(logits[:, -1], -1)[:, None].astype(jnp.int32)
out = [int(tok[0, 0])]
step_d = jax.jit(lambda p, c, t, pos: lm_decode_step(p, c, t, pos, cfg))
for i in range(16):
    logits, cache = step_d(params, cache, tok, jnp.int32(32 + i))
    tok = jnp.argmax(logits[:, -1], -1)[:, None].astype(jnp.int32)
    out.append(int(tok[0, 0]))
print("prompt tail:", [int(t) for t in prompt[0, -8:]])
print("generated  :", out)
print("(structure: x' = (31x + 7) mod V — a trained model continues it)")
