"""CTR prediction (§6.4): 4-mode (user, ad, publisher, page-section) binary
tensor; DFNTF vs logistic regression vs linear SVM, balanced clicks.

  PYTHONPATH=src python examples/ctr_prediction.py
"""
import numpy as np

from repro.core import baselines
from repro.core.model import DFNTF, FitConfig
from repro.data import balanced_train_test, kfold_split, make_sparse_tensor
from repro.utils.metrics import auc

tensor, _ = make_sparse_tensor("ctr_day", seed=0, max_nnz=2000)
rng = np.random.default_rng(0)
train_rows, test_rows = kfold_split(rng, tensor, folds=5)[0]
train, test = balanced_train_test(rng, tensor, train_rows, test_rows, binary=True)
print(f"CTR tensor dims={tensor.dims} (4-mode), clicks={tensor.nnz}")
print(f"train={len(train)} (clicks + sampled non-clicks), test={len(test)}")

model = DFNTF(tensor.dims, FitConfig(task="binary", rank=3, num_inducing=50,
                                     optimizer="adam", steps=150, learning_rate=2e-2))
model.fit(train)
a_ours = auc(test.y, model.predict_proba(test.idx))

lr = baselines.fit_linear(train, tensor.dims, loss_kind="logistic")
a_lr = auc(test.y, np.asarray(lr.score(np.asarray(test.idx))))
svm = baselines.fit_linear(train, tensor.dims, loss_kind="hinge")
a_svm = auc(test.y, np.asarray(svm.score(np.asarray(test.idx))))

print(f"\nDFNTF (ours)        AUC = {a_ours:.4f}")
print(f"logistic regression AUC = {a_lr:.4f}")
print(f"linear SVM          AUC = {a_svm:.4f}")
print(f"improvement over LR: {100 * (a_ours - a_lr) / a_lr:+.1f}% (paper: ~+20%)")
