"""Quickstart: factorize a sparse continuous tensor with DFNTF.

Builds a synthetic 3-mode tensor with a NONLINEAR ground truth (RBF mixture
over concatenated latent factors — exactly the function class the paper's
model captures and a multilinear CP model cannot), trains the paper's model
with balanced zero/nonzero sampling, and compares against CP.

  PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.core import baselines
from repro.core.model import DFNTF, FitConfig
from repro.data import balanced_train_test, kfold_split, make_sparse_tensor
from repro.utils.metrics import mse

tensor, truth = make_sparse_tensor("alog", seed=0)
print(f"tensor dims={tensor.dims}, nnz={tensor.nnz} ({tensor.density:.2%} dense)")

rng = np.random.default_rng(0)
train_rows, test_rows = kfold_split(rng, tensor, folds=5)[0]
train, test = balanced_train_test(rng, tensor, train_rows, test_rows)
print(f"train={len(train)} entries (balanced zeros+nonzeros), test={len(test)}")

# ---- the paper's model: GP over concatenated per-mode latent factors
model = DFNTF(tensor.dims, FitConfig(task="continuous", rank=3, num_inducing=100,
                                     optimizer="adam", steps=300, learning_rate=2e-2))
model.fit(train, verbose=True)
ours = mse(test.y, model.predict(test.idx))

# ---- multilinear baseline on the same data
cp = baselines.fit_cp(train, tensor.dims, rank=3, steps=300)
cp_mse = mse(test.y, np.asarray(cp.score(test.idx)))

print(f"\nDFNTF (ours) test MSE: {ours:.4f}")
print(f"CP (multilinear) MSE : {cp_mse:.4f}")
print("nonlinear factorization wins" if ours < cp_mse else "CP wins (unexpected)")
