"""Binary tensor factorization: the Probit model + tight ELBO (Thm 4.2) with
the lambda fixed-point inner loop (Eq. 8 / Lemma 4.3).

Uses the Enron-footprint knowledge tensor; evaluates AUC on balanced
held-out entries, the §6.1 protocol.

  PYTHONPATH=src python examples/binary_tensor.py
"""
import numpy as np

from repro.core.model import DFNTF, FitConfig
from repro.data import balanced_train_test, kfold_split, make_sparse_tensor
from repro.utils.metrics import auc

tensor, _ = make_sparse_tensor("enron", seed=0)
rng = np.random.default_rng(0)
train_rows, test_rows = kfold_split(rng, tensor, folds=5)[0]
train, test = balanced_train_test(rng, tensor, train_rows, test_rows, binary=True)
print(f"enron-like: dims={tensor.dims} nnz={tensor.nnz}; train={len(train)} test={len(test)}")

model = DFNTF(
    tensor.dims,
    FitConfig(task="binary", rank=3, num_inducing=50, optimizer="adam",
              steps=150, learning_rate=2e-2, fixed_point_iters=5),
)
model.fit(train, verbose=True)
p = model.predict_proba(test.idx)
print(f"\ntest AUC = {auc(test.y, p):.4f}")
print(f"final tight ELBO L2* = {model.elbo():.2f}")
