"""Launch-layer unit tests (no compilation, no device allocation):
input specs, long-context variant resolution, microbatch policy, and the
analytic-vs-ShapeDtypeStruct consistency of the decode caches."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import SHAPES, get_arch, list_archs
from repro.launch.steps import (
    abstract_params,
    decode_cache_specs,
    input_specs,
    resolve_arch_for_shape,
    train_batch_specs,
)

jax.config.update("jax_enable_x64", True)


@pytest.mark.parametrize("arch", list_archs())
@pytest.mark.parametrize("shape_name", list(SHAPES))
def test_input_specs_cover_all_pairs(arch, shape_name):
    cfg = get_arch(arch)
    shape = SHAPES[shape_name]
    specs = input_specs(cfg, shape)
    leaves = jax.tree.leaves(specs)
    assert leaves, (arch, shape_name)
    # ShapeDtypeStructs only — never allocated arrays
    assert all(isinstance(l, jax.ShapeDtypeStruct) for l in leaves)
    if shape.mode == "train":
        toks = specs["batch"]["tokens"]
        assert toks.shape[0] == shape.global_batch
        if cfg.modality == "vision":
            assert toks.shape[1] == shape.seq_len - cfg.frontend_tokens
            assert specs["batch"]["patch_embeds"].shape[1] == cfg.frontend_tokens
        else:
            assert toks.shape[1] == shape.seq_len
    elif shape.mode == "decode":
        assert specs["tokens"].shape == (shape.global_batch, 1)
        assert specs["pos"].shape == ()


def test_long_context_variant_resolution():
    long = SHAPES["long_500k"]
    for arch in list_archs():
        cfg0 = get_arch(arch)
        cfg, variant = resolve_arch_for_shape(cfg0, long)
        assert cfg.supports_seq_len(long.seq_len)
        if cfg0.family in ("ssm", "hybrid") or cfg0.sliding_window:
            assert variant == ""  # native sub-quadratic
        else:
            assert variant == "+swa" and cfg.sliding_window > 0
        # short shapes never mutate the config
        cfg_t, v_t = resolve_arch_for_shape(cfg0, SHAPES["train_4k"])
        assert cfg_t == cfg0 and v_t == ""


def test_decode_cache_specs_window_capped():
    long = SHAPES["long_500k"]
    # SWA variant: kv cache is the 4096 ring, not 524288
    cfg, _ = resolve_arch_for_shape(get_arch("qwen2-72b"), long)
    cache = decode_cache_specs(cfg, long)
    assert cache["kv"]["k"].shape[2] == 4096
    # full attention at 32k: linear cache of the whole context
    cfg32, _ = resolve_arch_for_shape(get_arch("qwen2-72b"), SHAPES["decode_32k"])
    cache32 = decode_cache_specs(cfg32, SHAPES["decode_32k"])
    assert cache32["kv"]["k"].shape[2] == 32768
    # SSM: O(1) state, no kv
    cfgm, _ = resolve_arch_for_shape(get_arch("mamba2-1.3b"), long)
    cm = decode_cache_specs(cfgm, long)
    assert "kv" not in cm and cm["ssm"]["state"].shape[1] == 1


def test_abstract_params_match_reduced_structure():
    """Full-config abstract params and real reduced params have the same
    tree structure (so shardings built on one apply to the other)."""
    from repro.configs import get_reduced
    from repro.models import init_lm_params

    for arch in ("qwen3-0.6b", "mixtral-8x22b", "zamba2-1.2b", "llava-next-mistral-7b"):
        full = abstract_params(get_arch(arch))
        red = init_lm_params(jax.random.PRNGKey(0), get_reduced(arch))
        assert jax.tree.structure(full) == jax.tree.structure(red)


def test_default_microbatches_divisibility():
    # importing dryrun only sets XLA_FLAGS (inert: jax devices are already
    # locked to 1 in-process); the mesh is duck-typed — the policy only
    # reads .axis_names and .shape.
    from repro.launch.dryrun import default_microbatches  # noqa: PLC0415

    class FakeMesh:
        axis_names = ("data", "model")
        shape = {"data": 16, "model": 16}

    mesh = FakeMesh()
    for arch in list_archs():
        cfg = get_arch(arch)
        for shape in SHAPES.values():
            m = default_microbatches(cfg, shape, mesh)
            assert shape.global_batch % m == 0
            if shape.mode != "train":
                assert m == 1
