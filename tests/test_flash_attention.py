"""Pallas flash-attention kernel vs the jnp oracle (interpret=True on CPU).

Shape/dtype sweep per the kernel-testing convention: GQA ratios, causal and
sliding-window masks, padding (S not a multiple of the block), bf16 + f32.
Also a hypothesis property test: softmax weights are a convex combination,
so each output must lie inside the per-row min/max envelope of V.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.kernels.flash_attention import attention_ref, flash_attention

jax.config.update("jax_enable_x64", True)


def _rand(key, B, S, H, Hk, hd, dtype):
    kq, kk, kv = jax.random.split(key, 3)
    q = jax.random.normal(kq, (B, S, H, hd), jnp.float32).astype(dtype)
    k = jax.random.normal(kk, (B, S, Hk, hd), jnp.float32).astype(dtype)
    v = jax.random.normal(kv, (B, S, Hk, hd), jnp.float32).astype(dtype)
    return q, k, v


@pytest.mark.parametrize("S,H,Hk,hd,window,bq,bk", [
    (256, 4, 4, 64, 0, 128, 128),
    (256, 8, 2, 64, 0, 128, 128),
    (512, 4, 1, 32, 0, 128, 128),
    (256, 4, 2, 64, 128, 128, 128),
    (512, 2, 2, 64, 256, 128, 128),
    (256, 2, 2, 128, 0, 64, 128),
    (384, 2, 1, 64, 0, 128, 128),  # S not a multiple of block: padding path
    (192, 2, 2, 64, 64, 64, 64),
])
def test_flash_matches_ref_f32(S, H, Hk, hd, window, bq, bk):
    q, k, v = _rand(jax.random.PRNGKey(0), 2, S, H, Hk, hd, jnp.float32)
    got = flash_attention(q, k, v, window=window, block_q=bq, block_kv=bk, interpret=True)
    want = flash_attention(q, k, v, window=window, use_ref=True)
    np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("dtype", [jnp.bfloat16, jnp.float32])
def test_flash_dtypes(dtype):
    q, k, v = _rand(jax.random.PRNGKey(1), 1, 256, 4, 2, 64, dtype)
    got = flash_attention(q, k, v, interpret=True)
    want = flash_attention(q, k, v, use_ref=True)
    assert got.dtype == dtype
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(
        got.astype(jnp.float32), want.astype(jnp.float32), rtol=tol, atol=tol
    )


def test_flash_noncausal():
    q, k, v = _rand(jax.random.PRNGKey(2), 2, 256, 2, 2, 64, jnp.float32)
    got = flash_attention(q, k, v, causal=False, interpret=True)
    want = flash_attention(q, k, v, causal=False, use_ref=True)
    np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-5)


@settings(max_examples=10, deadline=None)
@given(
    seed=st.integers(0, 2**31 - 1),
    S=st.sampled_from([128, 256]),
    H=st.sampled_from([2, 4]),
    window=st.sampled_from([0, 64]),
)
def test_flash_output_in_value_envelope(seed, S, H, window):
    """Attention output is a convex combination of visible values."""
    q, k, v = _rand(jax.random.PRNGKey(seed), 1, S, H, H, 32, jnp.float32)
    out = flash_attention(q, k, v, window=window, interpret=True)
    lo = jnp.min(v, axis=1, keepdims=True) - 1e-4
    hi = jnp.max(v, axis=1, keepdims=True) + 1e-4
    assert bool(jnp.all(out >= lo)) and bool(jnp.all(out <= hi))


def test_flash_agrees_with_model_zoo_attention():
    """The kernel, its oracle, and the model zoo's chunked jnp attention all
    implement the same mask semantics."""
    from repro.models.layers import chunked_attention

    q, k, v = _rand(jax.random.PRNGKey(3), 2, 256, 4, 2, 64, jnp.float32)
    a = flash_attention(q, k, v, window=64, interpret=True)
    b = chunked_attention(q, k, v, causal=True, window=64, q_chunk=64, kv_chunk=64)
    np.testing.assert_allclose(a, b, rtol=2e-5, atol=2e-5)
