"""Unit + property tests for the covariance functions and input gathering."""
import hypothesis
import hypothesis.strategies as st
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import gp

KINDS = list(gp.KERNEL_KINDS)


def _random_inputs(seed, n, m, d, dtype=jnp.float64):
    k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
    return (
        jax.random.normal(k1, (n, d), dtype),
        jax.random.normal(k2, (m, d), dtype),
    )


@pytest.mark.parametrize("kind", KINDS)
def test_symmetry_and_diag(kind):
    xs, _ = _random_inputs(0, 9, 5, 4)
    kp = gp.init_kernel_params(kind, 4, lengthscale=0.7, amplitude=1.3, dtype=jnp.float64)
    kxx = gp.kernel_matrix(kind, kp, xs, xs)
    np.testing.assert_allclose(kxx, kxx.T, rtol=1e-12)
    np.testing.assert_allclose(jnp.diag(kxx), gp.kernel_diag(kind, kp, xs), rtol=1e-10)


@pytest.mark.parametrize("kind", KINDS)
def test_psd(kind):
    xs, _ = _random_inputs(1, 12, 5, 3)
    kp = gp.init_kernel_params(kind, 3, dtype=jnp.float64)
    kxx = gp.kernel_matrix(kind, kp, xs, xs)
    eigs = np.linalg.eigvalsh(np.asarray(kxx))
    assert eigs.min() > -1e-8


@pytest.mark.parametrize("kind", ["rbf", "matern32", "matern52"])
def test_stationary_bounds(kind):
    """0 < k(x, z) <= amp^2, equality iff x == z."""
    xs, zs = _random_inputs(2, 8, 6, 5)
    kp = gp.init_kernel_params(kind, 5, amplitude=2.0, dtype=jnp.float64)
    kxz = gp.kernel_matrix(kind, kp, xs, zs)
    assert (kxz > 0).all()
    assert (kxz <= 4.0 + 1e-9).all()
    np.testing.assert_allclose(
        gp.kernel_matrix(kind, kp, xs[:1], xs[:1])[0, 0], 4.0, rtol=1e-9
    )


def test_ard_matches_iso_when_shared_lengthscale():
    xs, zs = _random_inputs(3, 7, 4, 6)
    kp_iso = gp.init_kernel_params("rbf", 6, lengthscale=0.5, dtype=jnp.float64)
    kp_ard = gp.init_kernel_params("ard", 6, lengthscale=0.5, dtype=jnp.float64)
    np.testing.assert_allclose(
        gp.kernel_matrix("rbf", kp_iso, xs, zs),
        gp.kernel_matrix("ard", kp_ard, xs, zs),
        rtol=1e-10,
    )


def test_linear_kernel_is_scaled_inner_product():
    xs, zs = _random_inputs(4, 5, 6, 3)
    kp = gp.init_kernel_params("linear", 3, lengthscale=2.0, amplitude=1.5, dtype=jnp.float64)
    expected = (1.5**2) * (xs / 2.0) @ (zs / 2.0).T
    np.testing.assert_allclose(gp.kernel_matrix("linear", kp, xs, zs), expected, rtol=1e-10)


def test_gather_inputs_concatenates_rows():
    key = jax.random.PRNGKey(0)
    dims, ranks = (5, 4, 6), (2, 3, 1)
    factors = tuple(
        jax.random.normal(jax.random.fold_in(key, k), (dims[k], ranks[k]), jnp.float64)
        for k in range(3)
    )
    idx = jnp.array([[0, 1, 2], [4, 3, 5]])
    xs = gp.gather_inputs(factors, idx)
    assert xs.shape == (2, 6)
    np.testing.assert_allclose(xs[0, :2], factors[0][0])
    np.testing.assert_allclose(xs[0, 2:5], factors[1][1])
    np.testing.assert_allclose(xs[1, 5:], factors[2][5])


@hypothesis.settings(deadline=None, max_examples=25)
@hypothesis.given(
    n=st.integers(1, 12),
    m=st.integers(1, 12),
    d=st.integers(1, 8),
    seed=st.integers(0, 2**16),
    ls=st.floats(0.1, 5.0),
    kind=st.sampled_from(KINDS),
)
def test_property_cross_cov_consistent_with_distance(n, m, d, seed, ls, kind):
    """Property: kernel matches elementwise scalar evaluation (vmap-free oracle)."""
    xs, zs = _random_inputs(seed, n, m, d)
    kp = gp.init_kernel_params(kind, d, lengthscale=ls, dtype=jnp.float64)
    kmat = np.asarray(gp.kernel_matrix(kind, kp, xs, zs))
    # scalar oracle
    xs_n, zs_n = np.asarray(xs) / ls, np.asarray(zs) / ls
    for i in range(0, n, max(1, n // 3)):
        for j in range(0, m, max(1, m // 3)):
            if kind == "linear":
                want = xs_n[i] @ zs_n[j]
            else:
                r2 = np.sum((xs_n[i] - zs_n[j]) ** 2)
                if kind in ("rbf", "ard"):
                    want = np.exp(-0.5 * r2)
                elif kind == "matern32":
                    s = np.sqrt(3 * r2 + 3e-12)
                    want = (1 + s) * np.exp(-s)
                else:
                    s = np.sqrt(5 * r2 + 5e-12)
                    want = (1 + s + s * s / 3) * np.exp(-s)
            np.testing.assert_allclose(kmat[i, j], want, rtol=1e-6, atol=1e-9)
