"""Distributed model-zoo correctness on a forced 8-device host mesh:

  * sharded (pjit + constraints + MoE shard_map) lm_loss == single-device;
  * flash-decoding sharded decode attention == local decode attention;
  * launch/steps lowering machinery (input_specs, step_shardings,
    make_train_step) compiles and runs on the small mesh.

Runs in SUBPROCESSES so the rest of the session keeps one device.
"""
import os
import subprocess
import sys

import pytest

_COMMON = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import dataclasses
import jax
import jax.numpy as jnp
import numpy as np
assert len(jax.devices()) == 8
"""

_SHARDED_LOSS = _COMMON + r"""
from repro.configs import get_reduced
from repro.distributed import batch_shardings, make_constrainer, param_shardings
from repro.models import init_lm_params, lm_loss

mesh = jax.make_mesh((4, 2), ("data", "model"))

for arch in ["qwen3-0.6b", "mixtral-8x22b", "qwen2-moe-a2.7b", "mamba2-1.3b", "zamba2-1.2b"]:
    cfg = dataclasses.replace(get_reduced(arch), dtype="float32")
    params = init_lm_params(jax.random.PRNGKey(0), cfg)
    B, S = 4, 64
    rng = np.random.default_rng(0)
    batch = {
        "tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)), jnp.int32),
        "labels": jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)), jnp.int32),
        "mask": jnp.ones((B, S), jnp.float32),
    }
    ref, _ = jax.jit(lambda p, b: lm_loss(p, b, cfg))(params, batch)

    p_sh = param_shardings(jax.eval_shape(lambda: params), cfg, mesh)
    b_sh = batch_shardings(jax.eval_shape(lambda: batch), mesh)
    params_s = jax.device_put(params, p_sh)
    batch_s = jax.device_put(batch, b_sh)
    constrain = make_constrainer(mesh)
    with jax.set_mesh(mesh):
        got, _ = jax.jit(
            lambda p, b: lm_loss(p, b, cfg, mesh=mesh, constrain=constrain)
        )(params_s, batch_s)
    # MoE capacity differs between 1-shard and 4-shard dispatch (local
    # capacity rounding), so allow a small tolerance for MoE archs.
    tol = 2e-2 if cfg.num_experts else 2e-5
    np.testing.assert_allclose(float(got), float(ref), rtol=tol)
    print("ok", arch, float(ref), float(got))
"""

_SHARDED_DECODE = _COMMON + r"""
from repro.configs import get_reduced
from repro.distributed import cache_shardings, param_shardings
from repro.models import init_decode_cache, init_lm_params, lm_decode_step

mesh = jax.make_mesh((4, 2), ("data", "model"))

for arch in ["qwen3-0.6b", "zamba2-1.2b"]:
    cfg = dataclasses.replace(get_reduced(arch), dtype="float32")
    params = init_lm_params(jax.random.PRNGKey(0), cfg)
    B, ctx = 4, 64
    cache = init_decode_cache(cfg, B, ctx)
    tok = jnp.asarray(np.random.default_rng(0).integers(0, cfg.vocab_size, (B, 1)), jnp.int32)

    ref_logits, _ = jax.jit(lambda p, c, t: lm_decode_step(p, c, t, jnp.int32(5), cfg))(
        params, cache, tok
    )

    p_sh = param_shardings(jax.eval_shape(lambda: params), cfg, mesh)
    c_sh = cache_shardings(jax.eval_shape(lambda: cache), cfg, mesh)
    params_s = jax.device_put(params, p_sh)
    cache_s = jax.device_put(cache, c_sh)
    with jax.set_mesh(mesh):
        got_logits, _ = jax.jit(
            lambda p, c, t: lm_decode_step(p, c, t, jnp.int32(5), cfg, mesh=mesh)
        )(params_s, cache_s, tok)
    np.testing.assert_allclose(
        np.asarray(got_logits), np.asarray(ref_logits), rtol=2e-4, atol=2e-4
    )
    print("ok", arch)
"""

_TRAIN_STEP = _COMMON + r"""
from repro.configs import ShapeConfig, get_reduced
from repro.distributed import batch_shardings, param_shardings
from repro.launch.steps import make_train_step
from repro.models import init_lm_params
from repro.optim import adam

mesh = jax.make_mesh((4, 2), ("data", "model"))
cfg = dataclasses.replace(get_reduced("qwen3-0.6b"), dtype="float32")
params = init_lm_params(jax.random.PRNGKey(0), cfg)
opt, step = make_train_step(cfg, mesh, microbatches=2, learning_rate=1e-3)
opt_state = opt.init(params)
B, S = 8, 64
rng = np.random.default_rng(0)
batch = {
    "tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)), jnp.int32),
    "labels": jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)), jnp.int32),
    "mask": jnp.ones((B, S), jnp.float32),
}
p_sh = param_shardings(jax.eval_shape(lambda: params), cfg, mesh)
b_sh = batch_shardings(jax.eval_shape(lambda: batch), mesh)
params = jax.device_put(params, p_sh)
batch = jax.device_put(batch, b_sh)
with jax.set_mesh(mesh):
    fn = jax.jit(step, donate_argnums=(0, 1))
    losses = []
    for i in range(3):
        params, opt_state, m = fn(params, opt_state, batch)
        losses.append(float(m["loss"]))
print("losses", losses)
assert losses[-1] < losses[0], losses
assert all(np.isfinite(l) for l in losses)
print("ok train step on mesh")
"""


@pytest.mark.parametrize(
    "name,script",
    [("sharded_loss", _SHARDED_LOSS), ("sharded_decode", _SHARDED_DECODE), ("train_step", _TRAIN_STEP)],
)
def test_distributed_model(name, script):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    proc = subprocess.run(
        [sys.executable, "-c", script], env=env, capture_output=True, text=True, timeout=1200
    )
    assert proc.returncode == 0, f"{name}\nSTDOUT:\n{proc.stdout}\nSTDERR:\n{proc.stderr[-3000:]}"
