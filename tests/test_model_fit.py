"""End-to-end behaviour: DFNTF learns nonlinear synthetic tensors and beats
the multilinear baselines on them (the paper's central claim, Fig. 1)."""
import numpy as np
import pytest

from repro.core import baselines
from repro.core.model import DFNTF, FitConfig
from repro.data import balanced_train_test, kfold_split, make_sparse_tensor
from repro.data.synthetic import make_ground_truth
from repro.data.tensor_store import EntrySet, SparseTensor, random_entries
from repro.utils.metrics import auc, mse


def _small_continuous(seed=0, n_train=600, n_test=200, dims=(25, 20, 15)):
    rng = np.random.default_rng(seed)
    truth = make_ground_truth(rng, dims, rank=2, num_centers=10, bandwidth=2.0, noise_std=0.02)
    idx = random_entries(rng, dims, n_train + n_test)
    f = truth.latent(idx)
    y = (f + rng.normal(size=len(f)) * truth.noise_std).astype(np.float32)
    train = EntrySet(idx[:n_train], y[:n_train])
    test = EntrySet(idx[n_train:], y[n_train:])
    return train, test, dims


def _small_binary(seed=0, n_train=800, n_test=300, dims=(25, 20, 15)):
    rng = np.random.default_rng(seed)
    truth = make_ground_truth(rng, dims, rank=2, num_centers=10, bandwidth=2.0)
    idx = random_entries(rng, dims, n_train + n_test)
    f = truth.latent(idx)
    f = (f - f.mean()) / (f.std() + 1e-9) * 2.0
    y = (rng.normal(size=len(f)) < f).astype(np.float32)  # probit ground truth
    return EntrySet(idx[:n_train], y[:n_train]), EntrySet(idx[n_train:], y[n_train:]), dims


def test_fit_continuous_adam_learns_and_beats_cp():
    train, test, dims = _small_continuous()
    cfg = FitConfig(
        task="continuous", rank=3, num_inducing=32, optimizer="adam",
        learning_rate=2e-2, steps=400, seed=0,
    )
    model = DFNTF(dims, cfg)
    hist = model.fit(train)
    assert hist["elbo"][-1] > hist["elbo"][0]  # optimized the bound
    pred = model.predict(test.idx)
    ours = mse(test.y, pred)
    var = float(np.var(test.y))
    assert ours < 0.5 * var, f"mse {ours} vs variance {var}"
    cp = baselines.fit_cp(train, dims, rank=3, steps=400)
    cp_mse = mse(test.y, np.asarray(cp.score(test.idx)))
    assert ours < cp_mse, f"DFNTF {ours} should beat CP {cp_mse} on nonlinear data"


def test_fit_continuous_lbfgs():
    train, test, dims = _small_continuous(seed=1)
    cfg = FitConfig(
        task="continuous", rank=3, num_inducing=32, optimizer="lbfgs",
        lbfgs_max_iters=120, seed=1,
    )
    model = DFNTF(dims, cfg)
    model.fit(train)
    ours = mse(test.y, model.predict(test.idx))
    assert ours < 0.5 * float(np.var(test.y))


def test_fit_binary_fixed_point_plus_adam():
    train, test, dims = _small_binary()
    cfg = FitConfig(
        task="binary", rank=3, num_inducing=32, optimizer="adam",
        learning_rate=2e-2, steps=250, fixed_point_iters=3, seed=0,
    )
    model = DFNTF(dims, cfg)
    hist = model.fit(train)
    assert hist["elbo"][-1] > hist["elbo"][0]
    proba = model.predict_proba(test.idx)
    assert np.isfinite(proba).all() and (proba >= 0).all() and (proba <= 1).all()
    score = auc(test.y, proba)
    assert score > 0.75, f"AUC {score}"


def test_chunked_fit_matches_unchunked_elbo():
    train, _, dims = _small_continuous(seed=2, n_train=256, n_test=10)
    base = DFNTF(dims, FitConfig(task="continuous", num_inducing=16, steps=0, seed=3))
    chunked = DFNTF(
        dims, FitConfig(task="continuous", num_inducing=16, steps=0, chunk=64, seed=3)
    )
    base.fit(train)
    chunked.fit(train)
    np.testing.assert_allclose(base.elbo(), chunked.elbo(), rtol=1e-5)


def test_balanced_sampling_improves_binary_auc():
    """CP vs CP-2 style check for our model's data-selection flexibility:
    training with balanced zeros must not collapse predictions to zero."""
    tensor, _ = make_sparse_tensor("enron", seed=0, max_nnz=400)
    rng = np.random.default_rng(0)
    (train_rows, test_rows), *_ = kfold_split(rng, tensor, folds=5)
    train, test = balanced_train_test(
        rng, tensor, train_rows, test_rows, binary=True
    )
    cfg = FitConfig(
        task="binary", rank=3, num_inducing=32, optimizer="adam",
        learning_rate=2e-2, steps=150, fixed_point_iters=2,
    )
    model = DFNTF(tensor.dims, cfg)
    model.fit(train)
    score = auc(test.y, model.predict_proba(test.idx))
    assert score > 0.6, f"AUC {score}"
