"""Tests for optim / data / checkpoint substrates."""
import os

import hypothesis
import hypothesis.strategies as st
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import checkpoint, optim
from repro.data import (
    balanced_train_test, kfold_split, make_sparse_tensor, minibatches,
    pad_to_multiple, sample_zero_entries,
)
from repro.data.tensor_store import EntrySet, SparseTensor

# ------------------------------------------------------------------ optim ---


def _rosenbrock(p):
    x, y = p["x"], p["y"]
    return (1.0 - x) ** 2 + 100.0 * (y - x * x) ** 2


def test_lbfgs_minimizes_rosenbrock():
    x0 = {"x": jnp.asarray(-1.2, jnp.float64), "y": jnp.asarray(1.0, jnp.float64)}
    res = optim.minimize(_rosenbrock, x0, max_iters=200, tol=1e-10)
    assert float(res.value) < 1e-12
    np.testing.assert_allclose(float(res.params["x"]), 1.0, atol=1e-5)
    np.testing.assert_allclose(float(res.params["y"]), 1.0, atol=1e-5)


def test_lbfgs_quadratic_exact_in_few_iters():
    a = jnp.asarray(np.diag([1.0, 10.0, 100.0]))

    def f(x):
        return 0.5 * x @ a @ x

    res = optim.minimize(f, jnp.ones(3, jnp.float64), max_iters=50, tol=1e-12)
    assert float(res.grad_norm) < 1e-10


def test_adam_converges_on_quadratic():
    opt = optim.adam(0.1)
    params = {"w": jnp.asarray([3.0, -2.0])}
    state = opt.init(params)

    @jax.jit
    def step(params, state):
        g = jax.grad(lambda p: jnp.sum(p["w"] ** 2))(params)
        upd, state = opt.update(g, state, params)
        return optim.apply_updates(params, upd), state

    for _ in range(300):
        params, state = step(params, state)
    assert float(jnp.max(jnp.abs(params["w"]))) < 1e-2


def test_clip_by_global_norm():
    opt = optim.clip_by_global_norm(1.0)
    g = {"a": jnp.asarray([3.0, 4.0])}  # norm 5
    upd, _ = opt.update(g, opt.init(g), None)
    np.testing.assert_allclose(
        float(jnp.linalg.norm(upd["a"])), 1.0, rtol=1e-5
    )


def test_schedules_monotone_sections():
    sch = optim.schedules.linear_warmup_cosine(1.0, 10, 100)
    vals = [float(sch(jnp.asarray(i))) for i in range(100)]
    assert vals[0] < vals[5] < vals[9]  # warmup rising
    assert vals[20] > vals[60] > vals[99]  # cosine decaying


# ------------------------------------------------------------------- data ---


def test_dataset_specs_footprints():
    t, _ = make_sparse_tensor("alog", seed=0)
    assert t.dims == (200, 100, 200)
    assert 0.002 < t.density < 0.005
    t2, _ = make_sparse_tensor("enron", seed=0)
    assert set(np.unique(t2.vals)) == {1.0}


def test_zero_sampling_disjoint_from_nonzeros():
    t, _ = make_sparse_tensor("adclick", seed=1)
    rng = np.random.default_rng(0)
    zeros = sample_zero_entries(rng, t, 500)
    nz = set(t.flat_index(t.idx).tolist())
    zf = t.flat_index(zeros)
    assert len(set(zf.tolist()) & nz) == 0
    assert len(np.unique(zf)) == 500


def test_balanced_split_protocol():
    t, _ = make_sparse_tensor("alog", seed=2, max_nnz=2000)
    rng = np.random.default_rng(0)
    folds = kfold_split(rng, t, folds=5)
    assert len(folds) == 5
    train_rows, test_rows = folds[0]
    assert len(train_rows) + len(test_rows) == t.nnz
    train, test = balanced_train_test(rng, t, train_rows, test_rows)
    # balanced: half of train entries are sampled zeros
    assert np.sum(train.y == 0) == len(train_rows)
    # train zeros disjoint from test zeros
    tr_flat = set(t.flat_index(train.idx[train.y == 0]).tolist())
    te_flat = set(t.flat_index(test.idx[test.y == 0]).tolist())
    assert not (tr_flat & te_flat)


@hypothesis.settings(deadline=None, max_examples=20)
@hypothesis.given(n=st.integers(1, 200), mult=st.integers(1, 64))
def test_property_padding(n, mult):
    es = EntrySet(np.zeros((n, 3), np.int32), np.ones(n, np.float32))
    b = pad_to_multiple(es, mult)
    assert len(b.y) % mult == 0
    assert b.w.sum() == n


def test_minibatches_cover_everything_once_per_epoch():
    es = EntrySet(
        np.arange(30, dtype=np.int32).reshape(10, 3), np.arange(10, dtype=np.float32)
    )
    batches = list(minibatches(es, 4, np.random.default_rng(0), epochs=1))
    ys = np.concatenate([b.y[b.w > 0] for b in batches])
    assert sorted(ys.tolist()) == list(range(10))


# -------------------------------------------------------------- checkpoint --


def test_checkpoint_roundtrip(tmp_path):
    tree = {
        "a": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
        "b": (jnp.asarray(2, jnp.int32), jnp.asarray([1.5], jnp.bfloat16)),
    }
    path = os.path.join(tmp_path, "x.ckpt.msgpack")
    checkpoint.save(path, tree)
    zeros = jax.tree.map(jnp.zeros_like, tree)
    back = checkpoint.restore(path, zeros)
    for l1, l2 in zip(jax.tree.leaves(tree), jax.tree.leaves(back)):
        np.testing.assert_array_equal(np.asarray(l1, np.float32), np.asarray(l2, np.float32))


def test_checkpoint_manager_retention(tmp_path):
    mgr = checkpoint.CheckpointManager(str(tmp_path), keep=2)
    tree = {"w": jnp.zeros(3)}
    for step in (1, 5, 9):
        mgr.save(step, jax.tree.map(lambda x: x + step, tree))
    assert mgr.all_steps() == [5, 9]
    restored, step = mgr.restore(tree)
    assert step == 9
    np.testing.assert_allclose(restored["w"], 9.0)


def test_checkpoint_shape_mismatch_raises(tmp_path):
    path = os.path.join(tmp_path, "x.ckpt.msgpack")
    checkpoint.save(path, {"w": jnp.zeros(3)})
    with pytest.raises(ValueError):
        checkpoint.restore(path, {"w": jnp.zeros(4)})
