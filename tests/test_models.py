"""Model-zoo correctness: layer oracles + per-arch smoke tests.

The important invariants:
  * chunked (flash-schedule) attention == naive masked softmax attention,
    for causal, sliding-window and GQA variants;
  * the chunked SSD scan == the naive per-step recurrence, and the decode
    step is consistent with it;
  * prefill (lm_forward) and token-by-token decode (lm_decode_step) produce
    the same logits;
  * MoE capacity dispatch == gather dispatch when nothing is dropped.

Plus: every one of the 10 assigned architectures instantiates its REDUCED
variant and runs one train step + one decode step with finite outputs.
"""
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import SHAPES, get_arch, get_reduced, list_archs
from repro.models import (
    init_decode_cache,
    init_lm_params,
    lm_decode_step,
    lm_forward,
    lm_loss,
)
from repro.models.layers import chunked_attention, decode_attention
from repro.models.moe import _local_moe, _local_moe_decode, init_moe_params
from repro.models.ssm import ssd_chunked, ssd_decode_step

jax.config.update("jax_enable_x64", True)


# ------------------------------------------------------------- attention


def naive_attention(q, k, v, causal=True, window=0):
    B, S, H, hd = q.shape
    Hk = k.shape[2]
    g = H // Hk
    qf = q.astype(jnp.float32).reshape(B, S, Hk, g, hd)
    s = jnp.einsum("bqhgd,bkhd->bhgqk", qf, k.astype(jnp.float32)) / math.sqrt(hd)
    i, j = jnp.arange(S)[:, None], jnp.arange(S)[None, :]
    mask = jnp.ones((S, S), bool)
    if causal:
        mask &= i >= j
    if window:
        mask &= i - j < window
    s = jnp.where(mask, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgqk,bkhd->bhgqd", p, v.astype(jnp.float32))
    return o.transpose(0, 3, 1, 2, 4).reshape(B, S, H, hd)


@pytest.mark.parametrize("S,H,Hk,window,qc", [
    (128, 4, 4, 0, 32),
    (128, 8, 2, 0, 64),
    (256, 4, 1, 0, 64),
    (128, 4, 2, 32, 32),
    (256, 8, 4, 64, 64),
])
def test_chunked_attention_matches_naive(S, H, Hk, window, qc):
    key = jax.random.PRNGKey(0)
    B, hd = 2, 16
    kq, kk, kv = jax.random.split(key, 3)
    q = jax.random.normal(kq, (B, S, H, hd), jnp.float32)
    k = jax.random.normal(kk, (B, S, Hk, hd), jnp.float32)
    v = jax.random.normal(kv, (B, S, Hk, hd), jnp.float32)
    got = chunked_attention(q, k, v, causal=True, window=window, q_chunk=qc, kv_chunk=qc)
    want = naive_attention(q, k, v, causal=True, window=window)
    np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-5)


def test_decode_attention_matches_last_row():
    key = jax.random.PRNGKey(1)
    B, S, H, Hk, hd = 2, 64, 4, 2, 16
    kq, kk, kv = jax.random.split(key, 3)
    q = jax.random.normal(kq, (B, S, H, hd), jnp.float32)
    k = jax.random.normal(kk, (B, S, Hk, hd), jnp.float32)
    v = jax.random.normal(kv, (B, S, Hk, hd), jnp.float32)
    full = naive_attention(q, k, v, causal=True)
    got = decode_attention(q[:, -1:], k, v, jnp.ones((B, S), bool))
    np.testing.assert_allclose(got[:, 0], full[:, -1], rtol=2e-5, atol=2e-5)


# ------------------------------------------------------------------ SSD


def naive_ssd(x, dt, A_log, Bm, Cm, D):
    """Step-by-step recurrence h_t = exp(a_t) h_{t-1} + dt_t B_t x_t."""
    Bsz, S, H, P = x.shape
    N = Bm.shape[-1]
    a = dt * (-jnp.exp(A_log))[None, None]
    h = jnp.zeros((Bsz, H, P, N))
    ys = []
    for t in range(S):
        h = h * jnp.exp(a[:, t])[..., None, None] + jnp.einsum(
            "bhp,bn->bhpn", x[:, t] * dt[:, t, :, None], Bm[:, t]
        )
        ys.append(jnp.einsum("bhpn,bn->bhp", h, Cm[:, t]) + x[:, t] * D[None, :, None])
    return jnp.stack(ys, axis=1), h


@pytest.mark.parametrize("S,chunk", [(32, 8), (64, 16), (64, 64), (48, 16)])
def test_ssd_chunked_matches_recurrence(S, chunk):
    key = jax.random.PRNGKey(2)
    Bsz, H, P, N = 2, 3, 8, 4
    ks = jax.random.split(key, 5)
    x = jax.random.normal(ks[0], (Bsz, S, H, P))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (Bsz, S, H)))
    A_log = jax.random.normal(ks[2], (H,)) * 0.5
    Bm = jax.random.normal(ks[3], (Bsz, S, N))
    Cm = jax.random.normal(ks[4], (Bsz, S, N))
    D = jnp.ones((H,))
    got, hT = ssd_chunked(x, dt, A_log, Bm, Cm, D, chunk=chunk)
    want, h_want = naive_ssd(x, dt, A_log, Bm, Cm, D)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(hT, h_want, rtol=1e-4, atol=1e-4)


def test_ssd_decode_step_consistent_with_chunked():
    key = jax.random.PRNGKey(3)
    Bsz, S, H, P, N = 2, 16, 3, 8, 4
    ks = jax.random.split(key, 5)
    x = jax.random.normal(ks[0], (Bsz, S, H, P))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (Bsz, S, H)))
    A_log = jax.random.normal(ks[2], (H,)) * 0.5
    Bm = jax.random.normal(ks[3], (Bsz, S, N))
    Cm = jax.random.normal(ks[4], (Bsz, S, N))
    D = jnp.ones((H,))
    want, _ = ssd_chunked(x, dt, A_log, Bm, Cm, D, chunk=8)
    h = jnp.zeros((Bsz, H, P, N))
    for t in range(S):
        y, h = ssd_decode_step(h, x[:, t], dt[:, t], A_log, Bm[:, t], Cm[:, t], D)
        np.testing.assert_allclose(y, want[:, t], rtol=1e-4, atol=1e-4)


# ------------------------------------------------------------------ MoE


def test_moe_capacity_matches_gather_when_no_drops():
    cfg = get_reduced("mixtral-8x22b")
    key = jax.random.PRNGKey(4)
    params = init_moe_params(key, cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(5), (32, cfg.d_model), jnp.float32) * 0.1
    # capacity factor large enough that nothing is dropped
    y_cap, _ = _local_moe(params, x, cfg, capacity_factor=float(cfg.num_experts), model_axis=None)
    y_gather, _ = _local_moe_decode(params, x, cfg, model_axis=None)
    np.testing.assert_allclose(y_cap, y_gather, rtol=1e-4, atol=1e-5)


def test_moe_shared_experts_present():
    cfg = get_reduced("qwen2-moe-a2.7b")
    assert cfg.num_shared_experts > 0
    params = init_moe_params(jax.random.PRNGKey(6), cfg, jnp.float32)
    assert "w_shared_gate" in params


# ------------------------------------------------- per-arch smoke tests


def _make_batch(cfg, B, S, key):
    kt, kp = jax.random.split(key)
    if cfg.modality == "vision":
        St = S - cfg.frontend_tokens
        return {
            "tokens": jax.random.randint(kt, (B, St), 0, cfg.vocab_size),
            "patch_embeds": jax.random.normal(kp, (B, cfg.frontend_tokens, 1024), jnp.float32) * 0.02,
            "labels": jax.random.randint(kt, (B, S), 0, cfg.vocab_size),
            "mask": jnp.concatenate(
                [jnp.zeros((B, cfg.frontend_tokens)), jnp.ones((B, St))], axis=1
            ),
        }
    return {
        "tokens": jax.random.randint(kt, (B, S), 0, cfg.vocab_size),
        "labels": jax.random.randint(kp, (B, S), 0, cfg.vocab_size),
        "mask": jnp.ones((B, S)),
    }


@pytest.mark.parametrize("arch", list_archs())
def test_arch_smoke_train_step(arch):
    cfg = get_reduced(arch)
    assert cfg.num_layers <= 4 and cfg.d_model <= 512
    if cfg.num_experts:
        assert cfg.num_experts <= 4
    key = jax.random.PRNGKey(0)
    params = init_lm_params(key, cfg)
    B, S = 2, 64
    batch = _make_batch(cfg, B, S, jax.random.PRNGKey(1))

    def loss_fn(p):
        return lm_loss(p, batch, cfg)[0]

    loss, grads = jax.value_and_grad(loss_fn)(params)
    assert jnp.isfinite(loss)
    # one SGD step changes the loss (params actually receive gradient)
    new_params = jax.tree.map(lambda p, g: p - 0.1 * g.astype(p.dtype), params, grads)
    loss2 = loss_fn(new_params)
    assert jnp.isfinite(loss2) and loss2 != loss
    leaves = jax.tree.leaves(grads)
    assert all(bool(jnp.all(jnp.isfinite(l))) for l in leaves)


@pytest.mark.parametrize("arch", list_archs())
def test_arch_smoke_decode_step(arch):
    cfg = get_reduced(arch)
    params = init_lm_params(jax.random.PRNGKey(0), cfg)
    B = 2
    cache = init_decode_cache(cfg, B, context_len=128)
    tok = jnp.zeros((B, 1), jnp.int32)
    logits, cache = jax.jit(lambda p, c, t, pos: lm_decode_step(p, c, t, pos, cfg))(
        params, cache, tok, jnp.int32(0)
    )
    assert logits.shape == (B, 1, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits)))


@pytest.mark.parametrize("arch", ["qwen3-0.6b", "mamba2-1.3b", "zamba2-1.2b", "mixtral-8x22b"])
def test_prefill_decode_consistency(arch):
    """Token-by-token decode reproduces the prefill logits (f32: the check is
    algorithmic exactness; bf16 accumulation drift is tested separately by
    the smoke tests' finiteness)."""
    import dataclasses

    cfg = dataclasses.replace(get_reduced(arch), dtype="float32")
    params = init_lm_params(jax.random.PRNGKey(0), cfg)
    B, S = 2, 16
    tokens = jax.random.randint(jax.random.PRNGKey(7), (B, S), 0, cfg.vocab_size)
    full_logits, _ = lm_forward(params, {"tokens": tokens}, cfg)

    cache = init_decode_cache(cfg, B, context_len=S)
    step = jax.jit(lambda p, c, t, pos: lm_decode_step(p, c, t, pos, cfg))
    for t in range(S):
        logits, cache = step(params, cache, tokens[:, t : t + 1], jnp.int32(t))
        np.testing.assert_allclose(
            np.asarray(logits[:, 0]), np.asarray(full_logits[:, t]), rtol=1e-4, atol=1e-4
        )


def test_sliding_window_ring_cache_consistency():
    """Ring-buffer SWA cache == full-history attention restricted to window."""
    import dataclasses

    cfg = get_reduced("qwen3-0.6b")
    cfg = dataclasses.replace(cfg, sliding_window=8, dtype="float32")
    params = init_lm_params(jax.random.PRNGKey(0), cfg)
    B, S = 2, 24
    tokens = jax.random.randint(jax.random.PRNGKey(8), (B, S), 0, cfg.vocab_size)
    full_logits, _ = lm_forward(params, {"tokens": tokens}, cfg)

    cache = init_decode_cache(cfg, B, context_len=S)  # ring of length 8
    assert cache["kv"]["k"].shape[2] == 8
    step = jax.jit(lambda p, c, t, pos: lm_decode_step(p, c, t, pos, cfg))
    for t in range(S):
        logits, cache = step(params, cache, tokens[:, t : t + 1], jnp.int32(t))
        np.testing.assert_allclose(
            np.asarray(logits[:, 0]), np.asarray(full_logits[:, t]), rtol=1e-4, atol=1e-4
        )


def test_long_context_support_flags():
    for arch in list_archs():
        cfg = get_arch(arch)
        long = SHAPES["long_500k"]
        if cfg.family in ("ssm", "hybrid") or cfg.sliding_window:
            assert cfg.supports_seq_len(long.seq_len)
        else:
            assert not cfg.supports_seq_len(long.seq_len)
            assert cfg.with_long_context_window().supports_seq_len(long.seq_len)
