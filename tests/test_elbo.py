"""Validate the tight ELBOs (Thm 4.1 / 4.2) against naive bound computations.

These are the core correctness proofs of the reproduction:
  * L1*(U, B) equals the Titsias bound L1(U, B, q) evaluated at the OPTIMAL
    Gaussian q(v) (computed independently, term by term), and upper-bounds it
    at suboptimal q.
  * L2*(U, B, lam) equals the intermediate bound L-tilde(lam, q(z)) at the
    optimal truncated-Gaussian q(z) (moments/entropy via scipy.truncnorm).
  * chunked == unchunked statistics; weighted padding is a no-op.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
import scipy.stats as sps

from repro.core import elbo as elbo_mod
from repro.core import gp, linalg
from repro.core.stats import binary_stats, sufficient_stats

DIMS = (6, 5, 4)
RANK = 2
P = 7
N = 40
KIND = "ard"


def _setup(seed=0, binary=False):
    key = jax.random.PRNGKey(seed)
    params = elbo_mod.init_params(
        key, DIMS, RANK, num_inducing=P, kernel_kind=KIND,
        factor_scale=0.5, beta=2.0, dtype=jnp.float64,
    )
    kidx, ky, klam = jax.random.split(jax.random.fold_in(key, 1), 3)
    idx = jnp.stack(
        [jax.random.randint(jax.random.fold_in(kidx, k), (N,), 0, DIMS[k]) for k in range(3)],
        axis=1,
    )
    if binary:
        y = jax.random.bernoulli(ky, 0.5, (N,)).astype(jnp.float64)
        params = elbo_mod.DFNTFParams(
            factors=params.factors, inducing=params.inducing, kernel=params.kernel,
            log_beta=params.log_beta,
            lam=0.3 * jax.random.normal(klam, (P,), jnp.float64),
        )
    else:
        y = jax.random.normal(ky, (N,), jnp.float64)
    return params, idx, y


JIT = 1e-12


def _kernel_pieces(params, idx):
    xs = gp.gather_inputs(params.factors, idx)
    kbb = gp.kernel_matrix(KIND, params.kernel, params.inducing, params.inducing)
    kbb = np.asarray(linalg.add_jitter(kbb, JIT))  # same jitter convention as the bound
    kxb = np.asarray(gp.kernel_matrix(KIND, params.kernel, xs, params.inducing))
    kdiag = np.asarray(gp.kernel_diag(KIND, params.kernel, xs))
    return np.asarray(xs), kbb, kxb, kdiag


def _naive_l1_at_q(params, idx, y, mu, cov):
    """Titsias bound L1(q) computed term by term (Eq. 4), constants matching
    the paper's convention log p(U) =def= -1/2 sum ||U||_F^2."""
    _, kbb, kxb, kdiag = _kernel_pieces(params, idx)
    beta = float(params.beta)
    y = np.asarray(y)
    kbb_inv = np.linalg.inv(kbb)
    # -KL(q || N(0, Kbb))
    p = kbb.shape[0]
    kl = 0.5 * (
        np.trace(kbb_inv @ cov)
        + mu @ kbb_inv @ mu
        - p
        + np.linalg.slogdet(kbb)[1]
        - np.linalg.slogdet(cov)[1]
    )
    # sum_j E_q[F_v(y_j, beta)]
    a = kxb @ kbb_inv  # [N, p]
    mean_j = a @ mu
    sig2_j = kdiag - np.sum(a * kxb, axis=1)  # k_jj - k_jB Kbb^-1 k_Bj
    quad_j = np.sum((a @ cov) * a, axis=1)  # k_jB Kbb^-1 Cov Kbb^-1 k_Bj
    log_lik = (
        0.5 * np.log(beta / (2 * np.pi))
        - 0.5 * beta * (y - mean_j) ** 2
        - 0.5 * beta * quad_j
        - 0.5 * beta * sig2_j
    )
    log_prior_u = -0.5 * sum(float(jnp.sum(u * u)) for u in params.factors)
    return log_prior_u - kl + np.sum(log_lik)


def test_tight_elbo_continuous_equals_naive_at_optimum():
    params, idx, y = _setup()
    stats = sufficient_stats(KIND, params.kernel, params.factors, params.inducing, idx, y)
    tight = float(elbo_mod.elbo_continuous(KIND, params, stats, jitter=JIT))
    mu, cov = elbo_mod.optimal_qv_continuous(KIND, params, stats, jitter=JIT)
    naive = _naive_l1_at_q(params, idx, y, np.asarray(mu), np.asarray(cov))
    np.testing.assert_allclose(tight, naive, rtol=1e-8)


def test_tight_elbo_continuous_dominates_suboptimal_q():
    params, idx, y = _setup()
    stats = sufficient_stats(KIND, params.kernel, params.factors, params.inducing, idx, y)
    tight = float(elbo_mod.elbo_continuous(KIND, params, stats, jitter=JIT))
    rng = np.random.default_rng(0)
    for _ in range(5):
        mu = rng.normal(size=P)
        a = rng.normal(size=(P, P))
        cov = a @ a.T + np.eye(P)
        assert tight >= _naive_l1_at_q(params, idx, y, mu, cov) - 1e-9


def test_chunked_stats_match_unchunked():
    params, idx, y = _setup(seed=3)
    full = sufficient_stats(KIND, params.kernel, params.factors, params.inducing, idx, y)
    chunked = sufficient_stats(
        KIND, params.kernel, params.factors, params.inducing, idx, y, chunk=8
    )
    for name in ("a1", "a2", "a3", "a4", "n"):
        np.testing.assert_allclose(
            getattr(full, name), getattr(chunked, name), rtol=1e-10, err_msg=name
        )


def test_zero_weight_padding_is_noop():
    params, idx, y = _setup(seed=4)
    pad_idx = jnp.concatenate([idx, jnp.zeros((16, 3), idx.dtype)])
    pad_y = jnp.concatenate([y, jnp.full((16,), 7.0, y.dtype)])
    w = jnp.concatenate([jnp.ones((N,), y.dtype), jnp.zeros((16,), y.dtype)])
    full = sufficient_stats(KIND, params.kernel, params.factors, params.inducing, idx, y)
    padded = sufficient_stats(
        KIND, params.kernel, params.factors, params.inducing, pad_idx, pad_y, w
    )
    for name in ("a1", "a2", "a3", "a4", "n"):
        np.testing.assert_allclose(
            getattr(full, name), getattr(padded, name), rtol=1e-12, err_msg=name
        )


def test_elbo_gradient_matches_finite_differences():
    params, idx, y = _setup(seed=5)

    def loss(params):
        stats = sufficient_stats(
            KIND, params.kernel, params.factors, params.inducing, idx, y
        )
        return elbo_mod.elbo_continuous(KIND, params, stats)

    g = jax.grad(loss)(params)
    eps = 1e-6
    # spot-check a handful of coordinates across the pytree
    checks = [
        (lambda p, v: p.factors[0].at[2, 1].add(v), g.factors[0][2, 1]),
        (lambda p, v: p.inducing.at[3, 4].add(v), g.inducing[3, 4]),
        (lambda p, v: p.kernel.log_amplitude + v, g.kernel.log_amplitude),
        (lambda p, v: p.log_beta + v, g.log_beta),
    ]
    import dataclasses

    def rebuild(p, fn, v):
        if fn.__code__.co_consts and False:
            pass
        return None

    # finite differences via explicit param perturbation
    def perturb_factor(p, v):
        f = list(p.factors)
        f[0] = f[0].at[2, 1].add(v)
        return dataclasses.replace(p, factors=tuple(f))

    def perturb_inducing(p, v):
        return dataclasses.replace(p, inducing=p.inducing.at[3, 4].add(v))

    def perturb_amp(p, v):
        return dataclasses.replace(
            p, kernel=dataclasses.replace(p.kernel, log_amplitude=p.kernel.log_amplitude + v)
        )

    def perturb_beta(p, v):
        return dataclasses.replace(p, log_beta=p.log_beta + v)

    for perturb, got in [
        (perturb_factor, g.factors[0][2, 1]),
        (perturb_inducing, g.inducing[3, 4]),
        (perturb_amp, g.kernel.log_amplitude),
        (perturb_beta, g.log_beta),
    ]:
        fd = (loss(perturb(params, eps)) - loss(perturb(params, -eps))) / (2 * eps)
        np.testing.assert_allclose(got, fd, rtol=1e-4, atol=1e-6)


# ---------------------------------------------------------------- binary ----


def _naive_l2_tilde_at_optimal_qz(params, idx, y):
    """L-tilde (supplementary Eq. 14) at q*(z_j) = TruncNorm(lam^T k_j, 1, side y_j),
    with truncated-normal moments/entropy from scipy."""
    _, kbb, kxb, kdiag = _kernel_pieces(params, idx)
    lam = np.asarray(params.lam)
    y = np.asarray(y)
    a1 = kxb.T @ kxb
    m = kxb @ lam
    sgn = 2 * y - 1
    # TruncNorm on sign-constrained side: z >= 0 if y=1 else z <= 0.
    # +-37 sigma stands in for +-inf (scipy truncnorm.entropy NaNs on one-sided
    # infinite bounds in this version; pdf mass beyond 37 sigma is ~1e-297).
    lo = np.where(sgn > 0, 0.0, -37.0 + m)
    hi = np.where(sgn > 0, 37.0 + m, 0.0)
    a_std, b_std = (lo - m), (hi - m)
    tn = sps.truncnorm(a_std, b_std, loc=m, scale=1.0)
    ez = tn.mean()
    ez2 = tn.var() + ez**2
    ent = tn.entropy()
    s_mat = kbb + a1
    log_prior_u = -0.5 * sum(float(jnp.sum(u * u)) for u in params.factors)
    n = len(y)
    return (
        0.5 * np.linalg.slogdet(kbb)[1]
        - 0.5 * np.linalg.slogdet(s_mat)[1]
        - 0.5 * np.sum(ez2)
        - 0.5 * np.sum(kdiag)
        + 0.5 * np.trace(np.linalg.solve(kbb, a1))
        - 0.5 * n * np.log(2 * np.pi)
        + lam @ (kxb.T @ ez)
        - 0.5 * lam @ s_mat @ lam
        + np.sum(ent)  # \int q log p(y|z)/q = H[q] (p(y|z)=1 on the support)
        + log_prior_u
    )


def test_tight_elbo_binary_equals_naive_at_optimal_qz():
    params, idx, y = _setup(seed=7, binary=True)
    stats, s_phi, _ = binary_stats(
        KIND, params.kernel, params.factors, params.inducing, idx, y, params.lam
    )
    tight = float(elbo_mod.elbo_binary(KIND, params, stats, s_phi, jitter=JIT))
    naive = _naive_l2_tilde_at_optimal_qz(params, idx, y)
    np.testing.assert_allclose(tight, naive, rtol=1e-8)


def test_binary_stats_chunked_match():
    params, idx, y = _setup(seed=8, binary=True)
    full = binary_stats(
        KIND, params.kernel, params.factors, params.inducing, idx, y, params.lam
    )
    chunked = binary_stats(
        KIND, params.kernel, params.factors, params.inducing, idx, y, params.lam, chunk=10
    )
    np.testing.assert_allclose(full[1], chunked[1], rtol=1e-10)
    np.testing.assert_allclose(full[2], chunked[2], rtol=1e-10)
    np.testing.assert_allclose(full[0].a1, chunked[0].a1, rtol=1e-10)
