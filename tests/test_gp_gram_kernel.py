"""Pallas gp_gram kernel vs the jnp oracle: shape/dtype/kind sweeps
(interpret mode on CPU) + gradient equivalence via the custom VJP."""
import hypothesis
import hypothesis.strategies as st
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import gp
from repro.kernels.gp_gram import ops, ref


def _inputs(seed, n, p, d, dtype):
    ks = jax.random.split(jax.random.PRNGKey(seed), 4)
    xs = jax.random.normal(ks[0], (n, d), dtype)
    bs = jax.random.normal(ks[1], (p, d), dtype)
    y = jax.random.normal(ks[2], (n,), dtype)
    w = jax.random.uniform(ks[3], (n,), dtype)
    return xs, bs, y, w


def _assert_stats_close(got, want, rtol, atol=1e-3):
    for name in ("a1", "a2", "a3", "a4", "n"):
        np.testing.assert_allclose(
            np.asarray(getattr(got, name), np.float32),
            np.asarray(getattr(want, name), np.float32),
            rtol=rtol, atol=atol, err_msg=name,
        )


@pytest.mark.parametrize("kind", ["rbf", "ard", "matern32", "matern52", "linear"])
@pytest.mark.parametrize(
    "n,p,d", [(64, 16, 6), (100, 100, 9), (512, 100, 12), (1000, 50, 30)]
)
def test_kernel_matches_ref_f32(kind, n, p, d):
    xs, bs, y, w = _inputs(0, n, p, d, jnp.float32)
    kp = gp.init_kernel_params(kind, d, lengthscale=0.8, amplitude=1.2, dtype=jnp.float32)
    got = ops.gram_stats(kind, kp, xs, bs, y, w, tile_n=128)
    want = ref.gram_stats_ref(kind, kp, xs, bs, y, w)
    _assert_stats_close(got, want, rtol=5e-4)


@pytest.mark.parametrize("kind", ["ard", "matern52"])
def test_kernel_matches_ref_bf16_inputs(kind):
    xs, bs, y, w = _inputs(1, 256, 40, 8, jnp.float32)
    kp = gp.init_kernel_params(kind, 8, dtype=jnp.float32)
    got = ops.gram_stats(
        kind, kp, xs.astype(jnp.bfloat16), bs.astype(jnp.bfloat16),
        y.astype(jnp.bfloat16), w.astype(jnp.bfloat16), tile_n=128,
    )
    want = ref.gram_stats_ref(kind, kp, xs, bs, y, w)
    # bf16 feature stream: coarse tolerance, f32 accumulation keeps it sane
    _assert_stats_close(got, want, rtol=6e-2, atol=0.3)


def test_kernel_with_whitening_matches_ref():
    xs, bs, y, w = _inputs(2, 300, 32, 7, jnp.float32)
    kp = gp.init_kernel_params("ard", 7, dtype=jnp.float32)
    kbb = gp.kernel_matrix("ard", kp, bs, bs) + 1e-3 * jnp.eye(32)
    linv = jnp.linalg.inv(jnp.linalg.cholesky(kbb))
    got = ops.gram_stats("ard", kp, xs, bs, y, w, linv, tile_n=128)
    want = ref.gram_stats_ref("ard", kp, xs, bs, y, w, linv)
    _assert_stats_close(got, want, rtol=5e-4, atol=1e-4)


def test_zero_weight_padding_rows_noop():
    xs, bs, y, w = _inputs(3, 96, 24, 5, jnp.float32)
    kp = gp.init_kernel_params("rbf", 5, dtype=jnp.float32)
    got = ops.gram_stats("rbf", kp, xs, bs, y, w, tile_n=64)  # pads 96 -> 128
    want = ref.gram_stats_ref("rbf", kp, xs, bs, y, w)
    _assert_stats_close(got, want, rtol=5e-4)


def test_gradients_match_reference():
    xs, bs, y, w = _inputs(4, 128, 20, 6, jnp.float32)
    kp = gp.init_kernel_params("ard", 6, dtype=jnp.float32)

    def loss_pallas(kp, xs, bs):
        s = ops.gram_stats("ard", kp, xs, bs, y, w, tile_n=64)
        return jnp.sum(s.a1) + jnp.sum(s.a4) + s.a3

    def loss_ref(kp, xs, bs):
        s = ref.gram_stats_ref("ard", kp, xs, bs, y, w)
        return jnp.sum(s.a1) + jnp.sum(s.a4) + s.a3

    g1 = jax.grad(loss_pallas, argnums=(0, 1, 2))(kp, xs, bs)
    g2 = jax.grad(loss_ref, argnums=(0, 1, 2))(kp, xs, bs)
    for a, b in zip(jax.tree.leaves(g1), jax.tree.leaves(g2)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-4, atol=1e-5)


def test_stats_backend_pallas_end_to_end():
    """core.stats with backend='pallas' (chunked scan) == backend='jnp'."""
    from repro.core import stats as stats_mod

    key = jax.random.PRNGKey(0)
    dims, rank, p, n = (12, 10, 8), 2, 16, 256
    factors = tuple(
        0.3 * jax.random.normal(jax.random.fold_in(key, k), (dims[k], rank), jnp.float32)
        for k in range(3)
    )
    inducing = 0.3 * jax.random.normal(jax.random.fold_in(key, 9), (p, 6), jnp.float32)
    kp = gp.init_kernel_params("ard", 6, dtype=jnp.float32)
    idx = jnp.stack(
        [jax.random.randint(jax.random.fold_in(key, 20 + k), (n,), 0, dims[k]) for k in range(3)],
        axis=1,
    )
    y = jax.random.normal(jax.random.fold_in(key, 30), (n,), jnp.float32)
    a = stats_mod.sufficient_stats("ard", kp, factors, inducing, idx, y, backend="jnp")
    b = stats_mod.sufficient_stats(
        "ard", kp, factors, inducing, idx, y, backend="pallas", chunk=128
    )
    _assert_stats_close(b, a, rtol=3e-4)


@hypothesis.settings(deadline=None, max_examples=10)
@hypothesis.given(
    n=st.integers(8, 300),
    p=st.integers(1, 64),
    d=st.integers(1, 16),
    tile=st.sampled_from([32, 64, 128]),
    seed=st.integers(0, 1000),
)
def test_property_arbitrary_shapes_match(n, p, d, tile, seed):
    xs, bs, y, w = _inputs(seed, n, p, d, jnp.float32)
    kp = gp.init_kernel_params("rbf", d, dtype=jnp.float32)
    got = ops.gram_stats("rbf", kp, xs, bs, y, w, tile_n=tile)
    want = ref.gram_stats_ref("rbf", kp, xs, bs, y, w)
    _assert_stats_close(got, want, rtol=5e-4, atol=1e-4)
