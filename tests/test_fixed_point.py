"""Lemma 4.3: the lambda fixed-point iteration monotonically improves L2*
and converges."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import elbo as elbo_mod
from repro.core import fixed_point
from repro.core.stats import binary_stats

DIMS = (8, 6, 5)
RANK = 2
P = 9
N = 60
KIND = "rbf"


def _setup(seed=0):
    key = jax.random.PRNGKey(seed)
    params = elbo_mod.init_params(
        key, DIMS, RANK, num_inducing=P, kernel_kind=KIND,
        factor_scale=0.6, dtype=jnp.float64,
    )
    kidx, ky = jax.random.split(jax.random.fold_in(key, 1))
    idx = jnp.stack(
        [jax.random.randint(jax.random.fold_in(kidx, k), (N,), 0, DIMS[k]) for k in range(3)],
        axis=1,
    )
    y = jax.random.bernoulli(ky, 0.4, (N,)).astype(jnp.float64)
    return params, idx, y


def _l2star(params, idx, y):
    stats, s_phi, _ = binary_stats(
        KIND, params.kernel, params.factors, params.inducing, idx, y, params.lam
    )
    return float(elbo_mod.elbo_binary(KIND, params, stats, s_phi))


def test_fixed_point_monotone_and_convergent():
    params, idx, y = _setup()
    vals = [_l2star(params, idx, y)]
    lam_prev = params.lam
    deltas = []
    for _ in range(25):
        stats, _, a5 = binary_stats(
            KIND, params.kernel, params.factors, params.inducing, idx, y, params.lam
        )
        new_lam = fixed_point.lam_step(KIND, params, stats.a1, a5)
        deltas.append(float(jnp.max(jnp.abs(new_lam - lam_prev))))
        lam_prev = new_lam
        params = dataclasses.replace(params, lam=new_lam)
        vals.append(_l2star(params, idx, y))
    vals = np.array(vals)
    # monotone non-decreasing (tiny float tolerance)
    assert (np.diff(vals) >= -1e-7).all(), np.diff(vals).min()
    # strictly improved overall and converged
    assert vals[-1] > vals[0]
    assert deltas[-1] < 1e-6, deltas[-5:]


def test_run_fixed_point_driver_matches_manual():
    params, idx, y = _setup(seed=3)

    def stats_fn(p):
        stats, _, a5 = binary_stats(
            KIND, p.kernel, p.factors, p.inducing, idx, y, p.lam
        )
        return stats.a1, a5

    out, iters = fixed_point.run_fixed_point(KIND, params, stats_fn, max_iters=50, tol=1e-9)
    assert int(iters) > 1
    # lambda satisfies the fixed-point equation
    a1, a5 = stats_fn(out)
    resid = fixed_point.lam_step(KIND, out, a1, a5) - out.lam
    assert float(jnp.max(jnp.abs(resid))) < 1e-6
    # and improves the bound versus lam = 0
    assert _l2star(out, idx, y) > _l2star(params, idx, y)
