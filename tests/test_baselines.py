"""Baseline models: sanity + the paper's comparative claims on synthetic data."""
import numpy as np

from repro.core import baselines
from repro.data.synthetic import make_dense_nonlinear_tensor, make_ground_truth
from repro.data.tensor_store import EntrySet, random_entries
from repro.utils.metrics import auc, mse


def _continuous(seed=0, dims=(20, 15, 12), n=700):
    rng = np.random.default_rng(seed)
    truth = make_ground_truth(rng, dims, rank=2, num_centers=10)
    idx = random_entries(rng, dims, n)
    y = (truth.latent(idx) + rng.normal(size=n) * 0.05).astype(np.float32)
    return EntrySet(idx[:500], y[:500]), EntrySet(idx[500:], y[500:]), dims


def test_cp_learns_multilinear_data():
    """On PURELY multilinear ground truth CP should do well."""
    rng = np.random.default_rng(0)
    dims = (20, 15, 12)
    truth = make_ground_truth(rng, dims, rank=2, cp_weight=1.0, num_centers=0 or 1)
    # kill the nonlinear part
    truth = type(truth)(
        factors=truth.factors, centers=truth.centers, weights=truth.weights * 0,
        bandwidth=truth.bandwidth, cp_weight=1.0, noise_std=0.02,
    )
    idx = random_entries(rng, dims, 700)
    y = (truth.latent(idx) + rng.normal(size=700) * 0.02).astype(np.float32)
    train, test = EntrySet(idx[:500], y[:500]), EntrySet(idx[500:], y[500:])
    cp = baselines.fit_cp(train, dims, rank=3, steps=800)
    err = mse(test.y, np.asarray(cp.score(test.idx)))
    assert err < 0.3 * float(np.var(test.y)), err


def test_tucker_scores_finite_and_learns():
    train, test, dims = _continuous()
    tk = baselines.fit_tucker(train, dims, rank=3, steps=600)
    pred = np.asarray(tk.score(test.idx))
    assert np.isfinite(pred).all()
    assert mse(test.y, pred) < float(np.var(test.y))


def test_inftucker_fits_small_dense_tensor():
    rng = np.random.default_rng(0)
    dense, truth = make_dense_nonlinear_tensor(rng, (8, 7, 6), rank=2, noise_std=0.05)
    model = baselines.fit_inftucker(dense, rank=2, steps=100)
    grid = np.stack(np.meshgrid(*[np.arange(d) for d in (8, 7, 6)], indexing="ij"), -1)
    idx = grid.reshape(-1, 3)
    pred = baselines.inftucker_predict(model, (8, 7, 6), idx[:50])
    err = mse(dense.reshape(-1)[:50], pred)
    assert err < 0.5 * float(np.var(dense)), err


def test_linear_baselines_auc_above_chance():
    rng = np.random.default_rng(0)
    dims = (50, 40, 10)
    truth = make_ground_truth(rng, dims, rank=2)
    idx = random_entries(rng, dims, 1500)
    f = truth.latent(idx)
    f = (f - f.mean()) / (f.std() + 1e-9)
    y = (rng.normal(size=len(f)) * 0.5 < f).astype(np.float32)
    train, test = EntrySet(idx[:1000], y[:1000]), EntrySet(idx[1000:], y[1000:])
    for kind in ("logistic", "hinge"):
        lin = baselines.fit_linear(train, dims, loss_kind=kind, steps=300)
        score = auc(test.y, np.asarray(lin.score(test.idx)))
        assert score > 0.6, (kind, score)
