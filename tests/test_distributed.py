"""Sharded (key-value-free psum) inference == single-device inference.

Runs in a SUBPROCESS with XLA_FLAGS=--xla_force_host_platform_device_count=8
so the rest of the test session keeps seeing one device.
"""
import os
import subprocess
import sys

import pytest

_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import elbo as elbo_mod
from repro.core import inference
from repro.data.synthetic import make_ground_truth
from repro.data.tensor_store import random_entries

assert len(jax.devices()) == 8, jax.devices()

dims = (15, 12, 10)
rng = np.random.default_rng(0)
truth = make_ground_truth(rng, dims, rank=2)
idx_np = random_entries(rng, dims, 256)
f = truth.latent(idx_np)
y_np = (f + rng.normal(size=len(f)) * 0.05).astype(np.float32)
w_np = np.ones(256, np.float32)

mesh = jax.make_mesh((4, 2), ("data", "model"))

for task in ("continuous", "binary"):
    if task == "binary":
        y_use = (y_np > np.median(y_np)).astype(np.float32)
    else:
        y_use = y_np
    params = elbo_mod.init_params(
        jax.random.PRNGKey(0), dims, 2, num_inducing=12, factor_scale=0.4
    )
    if task == "binary":
        import dataclasses
        params = dataclasses.replace(
            params, lam=0.1 * jax.random.normal(jax.random.PRNGKey(1), (12,))
        )
    cfg = inference.InferenceConfig(task=task, data_axes=("data", "model"))
    cfg1 = inference.InferenceConfig(task=task)

    single = inference.make_loss_and_grad(cfg1, mesh=None)
    multi = inference.make_loss_and_grad(cfg, mesh=mesh)

    idx, y, w = jnp.asarray(idx_np), jnp.asarray(y_use), jnp.asarray(w_np)
    l1, g1 = single(params, idx, y, w)
    si, sy, sw = inference.shard_batch(mesh, cfg, idx, y, w)
    l2, g2 = multi(params, si, sy, sw)

    np.testing.assert_allclose(float(l1), float(l2), rtol=2e-5)
    for a, b in zip(jax.tree.leaves(g1), jax.tree.leaves(g2)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=2e-4, atol=2e-5)

    if task == "binary":
        up1 = inference.make_lambda_update(cfg1, mesh=None)
        up8 = inference.make_lambda_update(cfg, mesh=mesh)
        p1 = up1(params, idx, y, w)
        p8 = up8(params, si, sy, sw)
        np.testing.assert_allclose(
            np.asarray(p1.lam), np.asarray(p8.lam), rtol=2e-4, atol=2e-5
        )

# HLO must contain all-reduce (the key-value-free reduce), and no all-to-all
# (no shuffle!)
cfg = inference.InferenceConfig(task="continuous", data_axes=("data", "model"))
params = elbo_mod.init_params(jax.random.PRNGKey(0), dims, 2, num_inducing=12)
fn = inference.make_elbo_fn(cfg, mesh=mesh)
si, sy, sw = inference.shard_batch(
    mesh, cfg, jnp.asarray(idx_np), jnp.asarray(y_np), jnp.asarray(w_np)
)
txt = jax.jit(fn).lower(params, si, sy, sw).compile().as_text()
assert "all-reduce" in txt, "expected psum all-reduce in compiled HLO"
assert "all-to-all" not in txt, "data shuffling collective found; should be key-value-free"

print("DISTRIBUTED-OK")
"""


def test_sharded_matches_single_device():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.abspath(
        os.path.join(os.path.dirname(__file__), "..", "src")
    )
    out = subprocess.run(
        [sys.executable, "-c", _SCRIPT], capture_output=True, text=True, env=env,
        timeout=600,
    )
    assert out.returncode == 0, f"stdout:\n{out.stdout}\nstderr:\n{out.stderr}"
    assert "DISTRIBUTED-OK" in out.stdout
