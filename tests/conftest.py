"""Shared pytest config.

x64 is enabled so the ELBO identity tests (tight bound == naive bound at the
optimal variational posterior) can be checked to near machine precision.
All library code takes explicit dtypes, so enabling x64 here does not change
what the library computes for f32/bf16 callers.

NOTE: XLA_FLAGS / device-count forcing is deliberately NOT set here — smoke
tests and benchmarks must see the real single CPU device.  Distributed tests
that need multiple devices spawn subprocesses (see test_distributed.py).
"""
import jax

jax.config.update("jax_enable_x64", True)
