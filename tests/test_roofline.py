"""Roofline machinery: analytic op model vs fully-unrolled HLO, and the
collective-bytes HLO parser.

The analytic model must track compiled-HLO flops within a few percent when
every loop is unrolled (scan_unroll) — that is the calibration that lets the
dry-run report analytic flops at depths/sequence-lengths where full unrolling
is compile-time-prohibitive (see EXPERIMENTS.md §Dry-run methodology).
"""
import dataclasses

import jax
import jax.numpy as jnp
import pytest

from repro.configs import ShapeConfig, get_reduced
from repro.launch.steps import make_prefill_step, make_serve_step, make_train_step
from repro.models import init_decode_cache, init_lm_params
from repro.optim import adam
from repro.roofline.analytic import analytic_costs
from repro.roofline.hlo import collective_bytes, collective_link_bytes

jax.config.update("jax_enable_x64", True)

# one representative per family (dense, moe+shared, ssm, hybrid)
VALIDATION_ARCHS = ["qwen3-0.6b", "qwen2-moe-a2.7b", "mamba2-1.3b", "zamba2-1.2b"]


def _compiled_flops(cfg, mode, B=2, S=256):
    params = init_lm_params(jax.random.PRNGKey(0), cfg)
    if mode == "train":
        _, step = make_train_step(cfg, None, microbatches=1)
        opt = adam(1e-4).init(params)
        batch = {
            "tokens": jnp.zeros((B, S), jnp.int32),
            "labels": jnp.zeros((B, S), jnp.int32),
            "mask": jnp.ones((B, S)),
        }
        c = jax.jit(step).lower(params, opt, batch).compile()
    elif mode == "decode":
        step = make_serve_step(cfg, None)
        cache = init_decode_cache(cfg, B, S)
        c = (
            jax.jit(step)
            .lower(params, cache, jnp.zeros((B, 1), jnp.int32), jnp.int32(S - 1))
            .compile()
        )
    else:
        step = make_prefill_step(cfg, None)
        c = jax.jit(step).lower(params, {"tokens": jnp.zeros((B, S), jnp.int32)}).compile()
    return c.cost_analysis()["flops"]


@pytest.mark.parametrize("arch", VALIDATION_ARCHS)
@pytest.mark.parametrize("mode", ["train", "prefill", "decode"])
def test_analytic_flops_match_unrolled_hlo(arch, mode):
    B, S = 2, 256
    cfg = dataclasses.replace(get_reduced(arch), scan_unroll=True, inner_unroll=True, dtype="float32")
    shape = ShapeConfig("probe", S, B, mode)
    hlo = _compiled_flops(cfg, mode, B, S)
    ana = analytic_costs(cfg, shape, chips=1)["flops"]
    assert 0.9 < ana / hlo < 1.10, (arch, mode, ana, hlo, ana / hlo)


def test_analytic_attention_tiles():
    from repro.roofline.analytic import _attention_tiles

    # causal full: triangular block count
    assert _attention_tiles(1024, 256, 256, 0) == 4 * 5 // 2
    # sliding window: span capped at S
    assert _attention_tiles(1024, 256, 256, 256) == 4 * 2
    # window >= S behaves like full causal span
    assert _attention_tiles(512, 256, 256, 4096) == 2 * 2


# --------------------------------------------------- HLO collective parser


SAMPLE_HLO = """
ENTRY %main {
  %ag = bf16[8,256,1024]{2,1,0} all-gather(bf16[8,16,1024]{2,1,0} %p0), dimensions={1}
  %ar.1 = f32[1024,512]{1,0} all-reduce(f32[1024,512]{1,0} %x), to_apply=%add
  %ars = f32[64]{0} reduce-scatter(f32[1024]{0} %y), dimensions={0}
  %a2a = bf16[16,64]{1,0} all-to-all(bf16[16,64]{1,0} %z), dimensions={0}
  %cp = u32[4]{0} collective-permute(u32[4]{0} %w), source_target_pairs={{0,1}}
  %ag2 = (f32[128]{0}, f32[128]{0}) all-gather-start(f32[8]{0} %q), dimensions={0}
  %nothing = f32[2] add(f32[2] %a, f32[2] %b)
}
"""


def test_collective_bytes_parser():
    by_kind = collective_bytes(SAMPLE_HLO)
    assert by_kind["all-gather"] == 8 * 256 * 1024 * 2 + 2 * 128 * 4  # incl. async start
    assert by_kind["all-reduce"] == 1024 * 512 * 4
    assert by_kind["reduce-scatter"] == 64 * 4
    assert by_kind["all-to-all"] == 16 * 64 * 2
    assert by_kind["collective-permute"] == 4 * 4
    # ring model: all-reduce counts twice
    link = collective_link_bytes(by_kind)
    assert link == pytest.approx(
        by_kind["all-gather"]
        + 2 * by_kind["all-reduce"]
        + by_kind["reduce-scatter"]
        + by_kind["all-to-all"]
        + by_kind["collective-permute"]
    )


def test_model_flops_estimate_modes():
    from repro.configs import SHAPES, get_arch
    from repro.roofline.report import model_flops_estimate

    cfg = get_arch("qwen3-0.6b")
    n = cfg.active_param_count()
    t = SHAPES["train_4k"]
    assert model_flops_estimate(cfg, t) == 6.0 * n * t.global_batch * t.seq_len
    d = SHAPES["decode_32k"]
    assert model_flops_estimate(cfg, d) == 2.0 * n * d.global_batch
