"""The whitened-feature production path computes the SAME bounds as the raw
Theorem 4.1/4.2 forms (f64, shared tiny jitter), and stays finite in f32 at
extreme noise precision where the raw form fails."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import elbo as elbo_mod
from repro.core import gp, linalg
from repro.core.stats import binary_stats, sufficient_stats

DIMS = (7, 6, 5)
RANK = 2
P = 8
N = 50
KIND = "ard"
JIT = 1e-12


def _setup(seed=0, binary=False, dtype=jnp.float64):
    key = jax.random.PRNGKey(seed)
    params = elbo_mod.init_params(
        key, DIMS, RANK, num_inducing=P, kernel_kind=KIND,
        factor_scale=0.5, beta=3.0, dtype=dtype,
    )
    kidx, ky, klam = jax.random.split(jax.random.fold_in(key, 1), 3)
    idx = jnp.stack(
        [jax.random.randint(jax.random.fold_in(kidx, k), (N,), 0, DIMS[k]) for k in range(3)],
        axis=1,
    )
    if binary:
        y = jax.random.bernoulli(ky, 0.5, (N,)).astype(dtype)
        params = dataclasses.replace(
            params, lam=0.3 * jax.random.normal(klam, (P,), dtype)
        )
    else:
        y = jax.random.normal(ky, (N,), dtype)
    return params, idx, y


def test_whitened_continuous_matches_raw():
    params, idx, y = _setup()
    raw = sufficient_stats(KIND, params.kernel, params.factors, params.inducing, idx, y)
    tight_raw = float(elbo_mod.elbo_continuous(KIND, params, raw, jitter=JIT))
    chol_kbb, linv = elbo_mod.whiten_operator(KIND, params, jitter=JIT)
    wstats = sufficient_stats(
        KIND, params.kernel, params.factors, params.inducing, idx, y, None, linv
    )
    tight_w = float(elbo_mod.elbo_continuous_whitened(params, wstats, jitter=JIT))
    np.testing.assert_allclose(tight_w, tight_raw, rtol=1e-9)


def test_whitened_binary_matches_raw():
    params, idx, y = _setup(seed=3, binary=True)
    raw, s_phi_raw, a5_raw = binary_stats(
        KIND, params.kernel, params.factors, params.inducing, idx, y, params.lam
    )
    tight_raw = float(elbo_mod.elbo_binary(KIND, params, raw, s_phi_raw, jitter=JIT))
    chol_kbb, linv = elbo_mod.whiten_operator(KIND, params, jitter=JIT)
    lam_w = chol_kbb.T @ params.lam
    wstats, s_phi_w, a5_w = binary_stats(
        KIND, params.kernel, params.factors, params.inducing, idx, y, lam_w, None, linv
    )
    tight_w = float(elbo_mod.elbo_binary_whitened(params, wstats, s_phi_w, lam_w, jitter=JIT))
    np.testing.assert_allclose(tight_w, tight_raw, rtol=1e-9)
    np.testing.assert_allclose(s_phi_w, s_phi_raw, rtol=1e-9)
    # whitened a5 is L^{-1} a5
    np.testing.assert_allclose(a5_w, linv @ a5_raw, rtol=1e-8)


def test_whitened_lambda_step_matches_raw():
    from repro.core import fixed_point

    params, idx, y = _setup(seed=4, binary=True)
    raw, _, a5_raw = binary_stats(
        KIND, params.kernel, params.factors, params.inducing, idx, y, params.lam
    )
    new_raw = fixed_point.lam_step(KIND, params, raw.a1, a5_raw, jitter=JIT)
    chol_kbb, linv = elbo_mod.whiten_operator(KIND, params, jitter=JIT)
    lam_w = chol_kbb.T @ params.lam
    wstats, _, a5_w = binary_stats(
        KIND, params.kernel, params.factors, params.inducing, idx, y, lam_w, None, linv
    )
    new_w = elbo_mod.lam_step_whitened(wstats.a1, a5_w, lam_w, jitter=JIT)
    back = jax.scipy.linalg.solve_triangular(chol_kbb.T, new_w, lower=False)
    np.testing.assert_allclose(back, new_raw, rtol=1e-7, atol=1e-10)


def test_whitened_stays_finite_in_f32_at_huge_beta():
    """Regression for the f32 NaN: beta ~ 1e4 with near-singular Kbb."""
    params, idx, y = _setup(seed=5, dtype=jnp.float32)
    # near-singular Kbb: all inducing points almost identical
    params = dataclasses.replace(
        params,
        inducing=jnp.ones((P, params.inducing.shape[1]), jnp.float32)
        + 1e-3 * params.inducing,
        log_beta=jnp.asarray(jnp.log(1e4), jnp.float32),
    )
    chol_kbb, linv = elbo_mod.whiten_operator(KIND, params)
    wstats = sufficient_stats(
        KIND, params.kernel, params.factors, params.inducing, idx, y, None, linv
    )
    val = float(elbo_mod.elbo_continuous_whitened(params, wstats))
    assert np.isfinite(val), val
    g = jax.grad(
        lambda p: elbo_mod.elbo_continuous_whitened(
            p,
            sufficient_stats(
                KIND, p.kernel, p.factors, p.inducing, idx, y, None,
                elbo_mod.whiten_operator(KIND, p)[1],
            ),
        )
    )(params)
    assert all(np.isfinite(np.asarray(l)).all() for l in jax.tree.leaves(g))
